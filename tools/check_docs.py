"""Documentation link checker (CI docs job; also run by tests/test_docs.py).

Scans every tracked markdown file for local links/images and fails when a
target file doesn't exist — so README/docs references can't rot silently as
files move. External (http/mailto) links and pure in-page anchors are
skipped; a `path#anchor` link is checked for the file part only.

Run:  python tools/check_docs.py [repo_root]
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) and ![alt](target); stops at the first ')' — markdown
# targets here never contain parentheses.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "_cache", "node_modules"}


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def check_file(path: str, root: str):
    """-> list of (line_no, target) for broken local links in ``path``."""
    broken = []
    with open(path, encoding="utf-8") as f:
        in_code = False
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_code = not in_code
                continue
            if in_code:
                continue
            for m in _LINK.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                base = root if rel.startswith("/") else os.path.dirname(path)
                resolved = os.path.normpath(os.path.join(base,
                                                         rel.lstrip("/")))
                if not os.path.exists(resolved):
                    broken.append((lineno, target))
    return broken


def main(root: str = ".") -> int:
    root = os.path.abspath(root)
    n_files = n_links_broken = 0
    for path in sorted(md_files(root)):
        n_files += 1
        for lineno, target in check_file(path, root):
            print(f"BROKEN {os.path.relpath(path, root)}:{lineno} "
                  f"-> {target}")
            n_links_broken += 1
    print(f"checked {n_files} markdown files: "
          f"{'FAIL, ' + str(n_links_broken) + ' broken' if n_links_broken else 'all links resolve'}")
    return 1 if n_links_broken else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1] if len(sys.argv) > 1 else "."))
