from repro.data.synthetic import (calib_stream, lm_batch, lm_stream,
                                  vit_batch, vit_stream)

__all__ = ["lm_batch", "lm_stream", "vit_batch", "vit_stream", "calib_stream"]
