"""Deterministic synthetic data pipeline.

Design goals (DESIGN.md §2.3):
  * deterministic-by-index: batch(step) is a pure function of
    (seed, step, shard) — no inter-host coordination, no state to
    checkpoint beyond the integer cursor, natural straggler tolerance
    (a restarted host regenerates exactly its shard).
  * learnable: tasks have real structure so trained models develop the
    anisotropic/low-rank activations CORP exploits (paper App. A):
      - LM: order-2 markov chain over a Zipf-ish vocabulary with
        class-dependent transition sharpness,
      - vision: class prototypes + structured (low-rank) noise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM: markov chain over tokens
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _markov_table(vocab: int, seed: int):
    """Sparse-ish row-stochastic transition logits (vocab, vocab)."""
    rng = np.random.RandomState(seed)
    logits = rng.randn(vocab, vocab).astype(np.float32) * 2.0
    # each token prefers a small successor set -> learnable structure
    for i in range(vocab):
        hot = rng.choice(vocab, size=max(2, vocab // 64), replace=False)
        logits[i, hot] += 6.0
    p = np.exp(logits - logits.max(-1, keepdims=True))
    return p / p.sum(-1, keepdims=True)


def lm_batch(step: int, *, batch: int, seq: int, vocab: int, seed: int = 0,
             shard: int = 0, nshards: int = 1):
    """Returns {'tokens': (b, seq), 'labels': (b, seq)} for this shard."""
    table = _markov_table(vocab, seed)
    b = batch // nshards
    rng = np.random.RandomState(
        ((seed * 1_000_003 + step) * 977 + shard) % (2**31 - 1))
    toks = np.empty((b, seq + 1), np.int32)
    toks[:, 0] = rng.randint(0, vocab, size=b)
    # vectorized markov sampling
    u = rng.rand(b, seq).astype(np.float32)
    cdf = np.cumsum(table, axis=-1)
    for t in range(seq):
        rows = cdf[toks[:, t]]
        toks[:, t + 1] = (u[:, t][:, None] < rows).argmax(-1)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def lm_stream(*, batch, seq, vocab, seed=0, start_step=0, shard=0, nshards=1):
    step = start_step
    while True:
        yield step, lm_batch(step, batch=batch, seq=seq, vocab=vocab,
                             seed=seed, shard=shard, nshards=nshards)
        step += 1


# ---------------------------------------------------------------------------
# vision: prototype classes + low-rank structured noise
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _prototypes(n_classes: int, img: int, seed: int):
    rng = np.random.RandomState(seed + 7)
    protos = rng.randn(n_classes, img, img, 3).astype(np.float32)
    # smooth the prototypes (low-frequency structure)
    for _ in range(2):
        protos = 0.25 * (np.roll(protos, 1, 1) + np.roll(protos, -1, 1)
                         + np.roll(protos, 1, 2) + np.roll(protos, -1, 2))
    basis = rng.randn(8, img, img, 3).astype(np.float32) * 0.5
    return protos, basis


def vit_batch(step: int, *, batch: int, img: int, n_classes: int,
              seed: int = 0, shard: int = 0, nshards: int = 1,
              noise: float = 0.6, iid_noise: float = 0.1):
    protos, basis = _prototypes(n_classes, img, seed)
    b = batch // nshards
    rng = np.random.RandomState(
        ((seed * 999_983 + step) * 1009 + shard + 1) % (2**31 - 1))
    labels = rng.randint(0, n_classes, size=b)
    coef = rng.randn(b, basis.shape[0]).astype(np.float32)
    x = protos[labels] + noise * np.einsum("bk,khwc->bhwc", coef, basis)
    x = x + iid_noise * rng.randn(b, img, img, 3).astype(np.float32)
    return {"images": jnp.asarray(x), "labels": jnp.asarray(labels)}


def vit_stream(*, batch, img, n_classes, seed=0, start_step=0, shard=0,
               nshards=1):
    step = start_step
    while True:
        yield step, vit_batch(step, batch=batch, img=img,
                              n_classes=n_classes, seed=seed, shard=shard,
                              nshards=nshards)
        step += 1


# ---------------------------------------------------------------------------
# calibration streams (unlabeled, finite)
# ---------------------------------------------------------------------------

def calib_stream(cfg, *, n_samples: int, batch: int, seq: int = 64,
                 seed: int = 1234):
    """Zero-arg-callable factory: returns a fresh finite iterator each call
    (CORP traverses the stream twice). Unlabeled: label keys are dropped."""
    steps = max(1, n_samples // batch)

    def make():
        for i in range(steps):
            if cfg.family == "vit":
                b = vit_batch(10_000 + i, batch=batch, img=cfg.img_size,
                              n_classes=max(cfg.n_classes, 2), seed=seed)
                yield {"images": b["images"]}
            elif cfg.family == "encdec":
                b = lm_batch(10_000 + i, batch=batch, seq=seq,
                             vocab=cfg.vocab_size, seed=seed)
                rng = np.random.RandomState(seed + i)
                frames = rng.randn(batch, seq, cfg.d_model).astype(np.float32)
                yield {"frames": jnp.asarray(frames), "tokens": b["tokens"]}
            else:
                b = lm_batch(10_000 + i, batch=batch, seq=seq,
                             vocab=cfg.vocab_size, seed=seed)
                out = {"tokens": b["tokens"]}
                if cfg.frontend == "patch_stub":
                    rng = np.random.RandomState(seed + i)
                    out["patch_embeds"] = jnp.asarray(
                        rng.randn(batch, 8, cfg.d_model).astype(np.float32))
                yield out
    return make
