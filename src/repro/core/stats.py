"""Distributed calibration statistics for CORP.

Two streaming passes over the (unlabeled) calibration set:

  pass 1 (rank+mlp): per MLP unit the full first/second moments
      n, s1 = sum_t x_t, s2 = sum_t x_t x_t^T          (fp32)
    (s2's diagonal provides the ranking energies E[x^2]; its blocks provide
    Sigma_SS / Sigma_PS for the closed-form compensation — one pass covers
    both). Per attention unit the logit-energy ranking statistic
      s_j = sum_b (sum_{t,h} q_{t,j}^2)(sum_t k_{t,j}^2)   per kv group.

  pass 2 (attn compensation): given the kept index sets from ranking,
    the ridge system inputs (paper Eq. 15):
      G = sum_b (K_S^T K_S) (x) (Q_S^T Q_S),  h = sum_b vec((Q_S^T Q_P)(K_P^T K_S))
    for class-1 units, or the diagonal complex/real Hadamard reductions for
    rope-aware classes 2/3 (see repro.core.solve).

Every statistic is a *linear* reduction over calibration samples, so under
pjit the sums over the (data-sharded) batch axis compile to single psums —
CORP distributes embarrassingly (DESIGN.md §2.1). Statistics accumulate in
fp32 regardless of activation dtype (paper §Limitations); the *streaming*
dtype is whatever the taps arrive in — the engine's ``stats_dtype`` knob
emits bf16 taps to halve calibration HBM traffic, and dense second moments
carry that dtype into the gram kernel (fp32 VMEM accumulator, see
docs/kernels.md for the tolerance study).

One-traversal mode fuses both passes (``spec_pass2_reduce``): during pass 1
the engine *speculatively* accumulates pass-2 cross-moments against a fixed
top-k candidate keep-set per unit (chosen from running ranking scores with a
safety margin). Because every pass-2 statistic is built from per-sample
Gram blocks, the exact (G, h, t2) of ANY final keep-set that falls inside
the candidates can be reconstructed after the single traversal
(``spec_reconstruct``) — no second traversal. The identities:

  class 1:  G_SS   = restriction of  sum_b A_CC (x) C_CC   (A = Q^T Q etc.)
            h(S)   = [H_full - sum_{s in S} T_s]_SS, with
                     H_full = sum_b (Q_C^T Q)(K^T K_C) and T_s a diagonal
                     slice of the same candidate 4-tensor;
            t2(S)  = t2_tot - 2 sum_S diag(H_full) + sum_SxS E_CC
                     (inclusion-exclusion over P = complement(S))
  class 2/3: the Hadamard analogues on E = (Q^H Q) (.) conj(K^H K), whose
            candidate block doubles as both the ridge matrix and the
            t2 correction terms.

See docs/pipeline.md for the derivation, the margin policy, and the memory
bound (the class-1 candidate 4-tensor costs (1+margin)^4 x the two-pass G).

These are the reduction *definitions*; the streaming driver that fuses them
into one donated-accumulator step per batch is
``repro.core.calibrate.CalibrationEngine`` (``make_stats_step`` +
``pruner.accumulate`` remain as the legacy/reference path).
"""
from __future__ import annotations

import functools
import logging
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.units import Unit
from repro.kernels.gram import ops as gram_ops

log = logging.getLogger("repro.stats")

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _flat_tokens(x):
    """(..., F) -> (N, F), keeping the tap's streaming dtype.

    Taps arrive in the engine's ``stats_dtype`` (fp32 default, bf16 to
    halve calibration HBM traffic); the dense second moments must stream in
    that dtype all the way into the gram kernel, which casts per tile
    inside VMEM. Everything that accumulates is fp32 downstream.
    """
    return x.reshape(-1, x.shape[-1])


ACTIVE_EPS = 1e-2   # |x| > eps counts as 'active' (appendix E ranking)


def _moments(x):
    """x: (N, F) any float dtype -> dict(n, s1, s2, na), all fp32.

    The (F, F) second moment + column sums go through the gram op in x's
    own dtype, which dispatches to the Pallas streaming kernel on TPU
    (zero-padded to the block grid for arbitrary shapes; fp32 VMEM
    accumulator) and the plain-jnp reference elsewhere.
    """
    g = gram_ops.gram(x)
    return {"n": jnp.asarray(x.shape[0], jnp.float32),
            "s1": g["s1"],
            "s2": g["s2"],
            "na": jnp.sum((jnp.abs(x) > ACTIVE_EPS).astype(jnp.float32),
                          axis=0)}


def _sharded_moments(x, shard):
    """Model-sharded ``_moments``: x (..., N, F) -> same stat dict, with
    s1/s2/na column-sharded over ``shard.model_axis``.

    The second moment routes through ``gram_ops.gram_sharded`` (shard_map:
    each device runs the gram kernel on its local (N_local, F/m) column
    tile and psum-reduces over the batch axes), so no device materialises a
    full (F, F) Sigma. A token count that doesn't divide the data axes is
    zero-row-padded (invisible to every linear reduction; ``n`` keeps the
    true count). Only an F that doesn't divide the model axis falls back to
    the replicated path — returned as None and WARNED, because that unit
    then costs a full per-device Sigma.
    """
    sizes = shard.sizes
    m = shard.model_size
    baxes = shard.present_batch_axes
    d = int(np.prod([sizes[a] for a in baxes])) if baxes else 1
    N, F = x.shape[-2], x.shape[-1]
    if m <= 1 or F % m:
        if m > 1:
            log.warning(
                "sharded calibration: unit width F=%d does not divide the "
                "%r axis (%d-way) — this unit's Sigma stays REPLICATED "
                "(F*F fp32 per device)", F, shard.model_axis, m)
        return None
    if N % d:
        pad = d - N % d
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)])
    g = gram_ops.gram_sharded(x, shard.mesh, model_axis=shard.model_axis,
                              batch_axes=baxes)
    na = jnp.sum((jnp.abs(x) > ACTIVE_EPS).astype(jnp.float32), axis=-2)
    lead = x.shape[:-2]
    n = jnp.full(lead, float(N), jnp.float32) if lead \
        else jnp.asarray(float(N), jnp.float32)
    return {"n": n, "s1": g["s1"], "s2": g["s2"], "na": na}


def _unit_moments(h, stacked: bool, shard=None):
    """Dense-unit moments for a tap h (..., B, T, F) [+reps when stacked]."""
    if shard is not None:
        flat = h.reshape((h.shape[0], -1, h.shape[-1])) if stacked \
            else h.reshape(-1, h.shape[-1])
        out = _sharded_moments(flat, shard)
        if out is not None:
            return out
    fn = lambda a: _moments(_flat_tokens(a))
    return jax.vmap(fn)(h) if stacked else fn(h)


def _masked_moments(h, mask):
    """h: (E, C, F) per-expert hidden; mask: (E, C) validity."""
    hf = h.astype(jnp.float32) * mask[..., None]
    return {"n": jnp.sum(mask, axis=1),                      # (E,)
            "s1": jnp.sum(hf, axis=1),                       # (E, F)
            "s2": jnp.einsum("ecf,ecg->efg", hf, hf),        # (E, F, F)
            "na": jnp.sum((jnp.abs(hf) > ACTIVE_EPS).astype(jnp.float32)
                          * mask[..., None], axis=1)}


def _to_complex_pairs(q):
    """(..., D) -> complex (..., D/2): rotary pair (2i, 2i+1) -> x+iy."""
    return jax.lax.complex(q[..., 0::2], q[..., 1::2])


def _group_q(q, n_groups):
    """(B, T, H, d) -> (B, G, T*qpg, d): stack group queries along tokens."""
    B, T, H, d = q.shape
    qpg = H // n_groups
    return q.reshape(B, T, n_groups, qpg, d).transpose(0, 2, 1, 3, 4) \
            .reshape(B, n_groups, T * qpg, d)


# ---------------------------------------------------------------------------
# pass 1 reductions
# ---------------------------------------------------------------------------

def _p1_mlp(taps, unit: Unit):
    key = f"{unit.tap_prefix}/h"
    h = taps[key]
    if unit.stacked:
        return jax.vmap(lambda a: _moments(_flat_tokens(a)))(h)
    return _moments(_flat_tokens(h))


def _p1_moe(taps, unit: Unit):
    h = taps[f"{unit.tap_prefix}/moe_h"]        # (G,E,C,F) [+reps]
    mask = taps[f"{unit.tap_prefix}/moe_mask"]  # (G,E,C)
    yc = taps.get(f"{unit.tap_prefix}/moe_yc")  # (G,T,E,D) [+reps]
    yx = taps.get(f"{unit.tap_prefix}/moe_x")   # (G,T,D) [+reps]

    def one(hh, mm, cc=None, xx=None):
        # merge group dim into capacity
        G, E, C, F = hh.shape
        hh = hh.transpose(1, 0, 2, 3).reshape(E, G * C, F)
        mm = mm.transpose(1, 0, 2).reshape(E, G * C)
        out = _masked_moments(hh, mm)
        if cc is not None:
            # expert-removal moments: per token the block input x_t (D,)
            # concatenated with the gate-weighted expert contributions
            # c_te (D, per expert) -> z_t ((E+1)D,). Undispatched experts
            # contribute exact zeros. The ridge regresses removed experts'
            # contribution blocks onto the *input* block (whose
            # distribution is routing-invariant, so the fit survives the
            # post-prune gate renormalization); the contribution blocks'
            # diagonal traces are the expert ranking scores
            # (repro.core.pruner._fold_moe_experts, ranking.expert_scores).
            D = cc.shape[-1]
            z = jnp.concatenate(
                [xx.astype(jnp.float32).reshape(-1, D),
                 cc.astype(jnp.float32).reshape(-1, cc.shape[-2] * D)],
                axis=-1)
            out["yn"] = jnp.asarray(z.shape[0], jnp.float32)
            out["ys1"] = jnp.sum(z, axis=0)
            out["ys2"] = z.T @ z
        return out
    if unit.stacked:
        return jax.vmap(one)(h, mask) if yc is None \
            else jax.vmap(one)(h, mask, yc, yx)
    return one(h, mask) if yc is None else one(h, mask, yc, yx)


def _p1_attn(taps, unit: Unit, cfg):
    qk = "q" if unit.kind != "cross" else "cross_q"
    kk = "k" if unit.kind != "cross" else "cross_k"
    q = taps[f"{unit.tap_prefix}/{qk}"]  # (B,T,H,d) [+reps]
    k = taps[f"{unit.tap_prefix}/{kk}"]

    def one(q, k):
        # taps may stream bf16; the energy reductions accumulate fp32
        q = q.astype(jnp.float32)
        k = k.astype(jnp.float32)
        B = q.shape[0]
        G = unit.n_groups
        qg = _group_q(q, G)                       # (B,G,TQ,d)
        kg = k.transpose(0, 2, 1, 3)              # (B,G,T,d)  (Hkv == G)
        if unit.attn_class == 1:
            eq = jnp.sum(jnp.square(qg), axis=2)  # (B,G,d)
            ek = jnp.sum(jnp.square(kg), axis=2)
        else:
            qc, kc = _to_complex_pairs(qg), _to_complex_pairs(kg)
            eq = jnp.sum(jnp.square(jnp.abs(qc)), axis=2)
            ek = jnp.sum(jnp.square(jnp.abs(kc)), axis=2)
        return {"rank": jnp.sum(eq * ek, axis=0), "n": jnp.asarray(B, jnp.float32)}
    if unit.stacked:
        return jax.vmap(one)(q, k)
    return one(q, k)


# ---------------------------------------------------------------------------
# pass 2 reductions (attention compensation inputs)
# ---------------------------------------------------------------------------

def _p2_attn(taps, unit: Unit, keep, prune):
    """keep/prune: int32 arrays of kept/pruned indices.

    class 1: dims,  (G, ds) / (G, dp)          [+reps leading dim]
    class 2/3: rotary pairs, (G, dsp) / (G, dpp)
    """
    qk = "q" if unit.kind != "cross" else "cross_q"
    kk = "k" if unit.kind != "cross" else "cross_k"
    q = taps[f"{unit.tap_prefix}/{qk}"]
    k = taps[f"{unit.tap_prefix}/{kk}"]

    def one(q, k, keep, prune):
        # taps may stream bf16; ridge-system inputs accumulate fp32
        q = q.astype(jnp.float32)
        k = k.astype(jnp.float32)
        G = unit.n_groups
        qg = _group_q(q, G)                        # (B,G,TQ,d)
        kg = k.transpose(0, 2, 1, 3)               # (B,G,T,d)
        if unit.attn_class != 1:
            qg, kg = _to_complex_pairs(qg), _to_complex_pairs(kg)

        def per_group(qh, kh, S, P):
            # qh: (B, TQ, d); S: (ds,)
            qS = jnp.take(qh, S, axis=-1)
            qP = jnp.take(qh, P, axis=-1)
            kS = jnp.take(kh, S, axis=-1)
            kP = jnp.take(kh, P, axis=-1)
            if unit.attn_class == 1:
                A_ss = jnp.einsum("bts,btu->bsu", qS, qS)
                C_ss = jnp.einsum("bts,btu->bsu", kS, kS)
                A_sp = jnp.einsum("bts,btp->bsp", qS, qP)
                C_ps = jnp.einsum("btp,bts->bps", kP, kS)
                # row-major vec(M): vec(A M C) = (A (x) C) vec(M), C symmetric
                ds = qS.shape[-1]
                G_mat = jnp.einsum("bij,blk->biljk", A_ss, C_ss)
                G_mat = jnp.sum(G_mat, 0).reshape(ds * ds, ds * ds)
                h_vec = jnp.einsum("bsp,bpu->bsu", A_sp, C_ps)
                h_vec = jnp.sum(h_vec, 0).reshape(-1)
                t_norm = jnp.sum(jnp.square(
                    jnp.einsum("btp,bup->btu", qP, kP)))
                return {"G": G_mat, "h": h_vec, "t2": t_norm}
            # complex classes: Hadamard reduction
            A_ss = jnp.einsum("bts,btu->bsu", jnp.conj(qS), qS)
            C_ss = jnp.einsum("bts,btu->bsu", jnp.conj(kS), kS)
            A_sp = jnp.einsum("bts,btp->bsp", jnp.conj(qS), qP)
            C_ps = jnp.einsum("btp,bts->bps", jnp.conj(kP), kS)
            Gd = jnp.sum(A_ss * jnp.transpose(C_ss, (0, 2, 1)), 0)
            hd = jnp.sum(jnp.einsum("bsp,bps->bs", A_sp, C_ps), 0)
            t_norm = jnp.sum(jnp.square(jnp.abs(
                jnp.einsum("btp,bup->btu", qP, jnp.conj(kP)))))
            if unit.attn_class == 3:
                return {"G": jnp.real(Gd), "h": jnp.real(hd), "t2": t_norm}
            return {"G": Gd, "h": hd, "t2": t_norm}

        return jax.vmap(per_group, in_axes=(1, 1, 0, 0))(qg, kg, keep, prune)

    if unit.stacked:
        return jax.vmap(one)(q, k, keep, prune)
    return one(q, k, keep, prune)


# ---------------------------------------------------------------------------
# speculative pass-2 reductions (one-traversal calibration)
# ---------------------------------------------------------------------------

def _bgram(x, y):
    """Per-sample rectangular gram through the gram_cross kernel:
    x (..., N, Fx), y (..., N, Fy) -> (..., Fx, Fy) fp32 ``X_b^T Y_b``.
    Leading dims are flattened into one vmap axis; inputs keep their
    streaming dtype (bf16 tiles cast fp32 inside the kernel)."""
    lead = x.shape[:-2]
    xf = x.reshape((-1,) + x.shape[-2:])
    yf = y.reshape((-1,) + y.shape[-2:])
    out = jax.vmap(lambda a, b: gram_ops.gram_cross(a, b)["s2"])(xf, yf)
    return out.reshape(lead + out.shape[-2:])


def _p2spec_attn(taps, unit: Unit, cand):
    """Speculative pass-2 accumulators for one attention unit.

    cand: int32 candidate keep-indices (..., G, c) — dims for class 1,
    rotary pairs for classes 2/3 — fixed for the whole traversal. Per
    (layer, group) the leaves are:

      class 1:  Gc    (c, c, c, c)  sum_b A_CC (x) C_CC, order [i, l, j, k]
                Hfull (c, c)        sum_b (Q_C^T Q)(K^T K_C)
                t2_tot ()           sum_b <Q^T Q, K^T K>  (full Frobenius)
      class 2/3: Gc   (c, c) cplx   candidate block of E = A (.) conj(C)
                hfull (c,)   cplx   full row sums of E at candidate rows
                t2_tot ()           Re sum E

    Everything needed to reconstruct (G, h, t2) for any keep-set inside the
    candidates falls out of these via ``spec_reconstruct`` — the class-1
    per-keep outer products T_s and the t2 row/block corrections are
    diagonal slices of Gc/Hfull, so no extra accumulators are stored. All
    leaves accumulate fp32/complex64; dense grams route through the
    gram_cross kernel with candidate-index gathers on the results.
    """
    qk = "q" if unit.kind != "cross" else "cross_q"
    kk = "k" if unit.kind != "cross" else "cross_k"
    q = taps[f"{unit.tap_prefix}/{qk}"]
    k = taps[f"{unit.tap_prefix}/{kk}"]

    def one(q, k, cand):
        G = unit.n_groups
        if unit.attn_class == 1:
            # keep the streaming dtype: grams cast per tile in the kernel
            qg = _group_q(q, G)                    # (B, G, TQ, d)
            kg = k.transpose(0, 2, 1, 3)           # (B, G, T, d)

            def per_group(qh, kh, C):
                A_ff = _bgram(qh, qh)              # (B, d, d) fp32
                C_ff = _bgram(kh, kh)
                A_cc = jnp.take(jnp.take(A_ff, C, axis=-2), C, axis=-1)
                C_cc = jnp.take(jnp.take(C_ff, C, axis=-2), C, axis=-1)
                A_cf = jnp.take(A_ff, C, axis=-2)  # (B, c, d) = Q_C^T Q
                C_fc = jnp.take(C_ff, C, axis=-1)  # (B, d, c) = K^T K_C
                return {
                    "Gc": jnp.einsum("bij,blk->iljk", A_cc, C_cc),
                    "Hfull": jnp.einsum("bcp,bpu->cu", A_cf, C_fc),
                    "t2_tot": jnp.einsum("bpq,bpq->", A_ff, C_ff)}
            return jax.vmap(per_group, in_axes=(1, 1, 0))(qg, kg, cand)

        # complex classes: rotary pairs, Hadamard reductions (fp32 cast
        # before pairing — complex64 throughout)
        q32 = q.astype(jnp.float32)
        k32 = k.astype(jnp.float32)
        qc = _to_complex_pairs(_group_q(q32, G))   # (B, G, TQ, dp)
        kc = _to_complex_pairs(k32.transpose(0, 2, 1, 3))

        def per_group(qh, kh, C):
            A_ff = jnp.einsum("bts,btu->bsu", jnp.conj(qh), qh)
            C_ff = jnp.einsum("bts,btu->bsu", jnp.conj(kh), kh)
            E = A_ff * jnp.conj(C_ff)              # E_sp = A_sp conj(C_sp)
            Ec = jnp.take(E, C, axis=-2)           # candidate rows
            return {"Gc": jnp.sum(jnp.take(Ec, C, axis=-1), axis=0),
                    "hfull": jnp.sum(Ec, axis=(0, 2)),
                    "t2_tot": jnp.sum(jnp.real(E))}
        return jax.vmap(per_group, in_axes=(1, 1, 0))(qc, kc, cand)

    if unit.stacked:
        return jax.vmap(one)(q, k, cand)
    return one(q, k, cand)


def spec_pass2_reduce(taps: Dict, units: List[Unit], spec_plan: Dict) -> Dict:
    """Per-batch speculative pass-2 sums for every attention unit with a
    candidate set in ``spec_plan`` ({unit.name: (..., G, c) indices})."""
    out = {}
    for u in units:
        if u.kind in ("attn", "mla", "cross") and u.name in spec_plan:
            out[u.name] = _p2spec_attn(taps, u, spec_plan[u.name])
    return out


def spec_reconstruct(spec, cand, keep, unit: Unit) -> Dict:
    """Exact pass-2 statistics of ``keep`` from speculative accumulators.

    Host-side (numpy, float64 intermediates): valid whenever every group's
    keep-set is inside its candidate set (``ranking.covers``). Returns the
    same ``{"G", "h", "t2"}`` pytree — shapes and dtypes — that a dedicated
    ``pass2_reduce`` traversal would have produced for this unit, so the
    attention fold consumes it unchanged. The only deviation from the
    two-pass statistics is floating-point: the complement-set terms are
    differences of candidate/full sums rather than direct sums over P
    (docs/pipeline.md bounds the cancellation; ``t2`` is clamped at 0).
    """
    cls = unit.attn_class
    cand = np.asarray(cand)
    keep = np.asarray(keep)
    lead = cand.shape[:-1]                  # (reps..., G)
    c = cand.shape[-1]
    n = keep.shape[-1]
    cf = cand.reshape(-1, c)
    kf = keep.reshape(-1, n)
    rows = cf.shape[0]
    Gs, hs, t2s = [], [], []
    if cls == 1:
        Gc = np.asarray(spec["Gc"], np.float64).reshape(rows, c, c, c, c)
        Hf = np.asarray(spec["Hfull"], np.float64).reshape(rows, c, c)
        tt = np.asarray(spec["t2_tot"], np.float64).reshape(rows)
        for r in range(rows):
            pos = np.searchsorted(cf[r], kf[r])
            Gq = Gc[r]
            Gs.append(Gq[np.ix_(pos, pos, pos, pos)].reshape(n * n, n * n))
            # T_s = Gc[:, s, s, :] is the per-keep outer-product slice;
            # subtracting it from H_full leaves the pruned-set cross term
            sum_t = Gq[:, pos, pos, :].sum(axis=1)
            hs.append((Hf[r] - sum_t)[np.ix_(pos, pos)].reshape(-1))
            e_cc = np.einsum("iijj->ij", Gq)
            t2 = tt[r] - 2.0 * np.diagonal(Hf[r])[pos].sum() \
                + e_cc[np.ix_(pos, pos)].sum()
            t2s.append(max(t2, 0.0))
        out_dt = np.float32
    else:
        Gc = np.asarray(spec["Gc"], np.complex128).reshape(rows, c, c)
        hf = np.asarray(spec["hfull"], np.complex128).reshape(rows, c)
        tt = np.asarray(spec["t2_tot"], np.float64).reshape(rows)
        for r in range(rows):
            pos = np.searchsorted(cf[r], kf[r])
            Gd = Gc[r][np.ix_(pos, pos)]
            Gs.append(Gd)
            hs.append(hf[r][pos] - Gd.sum(axis=1))
            t2 = tt[r] - 2.0 * np.real(hf[r][pos].sum()) \
                + np.real(Gd.sum())
            t2s.append(max(t2, 0.0))
        out_dt = np.complex64
    G_arr = np.stack(Gs)
    h_arr = np.stack(hs)
    if cls == 3:                             # real restriction of class 2
        G_arr, h_arr = np.real(G_arr), np.real(h_arr)
        out_dt = np.float32
    return {"G": G_arr.astype(out_dt).reshape(lead + G_arr.shape[1:]),
            "h": h_arr.astype(out_dt).reshape(lead + h_arr.shape[1:]),
            "t2": np.asarray(t2s, np.float32).reshape(lead)}


# ---------------------------------------------------------------------------
# public: jit-able per-batch statistics steps
# ---------------------------------------------------------------------------

def pass1_reduce(taps: Dict, units: List[Unit], cfg, shard=None) -> Dict:
    """Per-batch pass-1 statistic sums for every unit, from one forward's
    taps.

    Args:
      taps: activation taps collected by ``model.apply(..., taps=taps)``.
      units: units to reduce (see ``repro.core.units``).
      cfg: model config (attention grouping metadata).
      shard: optional ``repro.distrib.sharding.CalibSharding`` — dense-unit
        second moments then route through the per-shard gram path
        (``_sharded_moments``); units whose shapes don't divide the mesh
        fall back to the replicated reduction (the pjit out-shardings still
        apply).

    Returns:
      ``{unit.name: stat dict}`` — mlp/moe/mamba: {n, s1, s2, na};
      attention: {rank: (G, d), n}.
    """
    out = {}
    for u in units:
        if u.kind in ("mlp", "rwkv_mlp", "mamba"):
            key = {"mlp": "h", "rwkv_mlp": "h", "mamba": "mamba_y"}[u.kind]
            out[u.name] = _unit_moments(taps[f"{u.tap_prefix}/{key}"],
                                        u.stacked, shard)
        elif u.kind == "moe":
            out[u.name] = _p1_moe(taps, u)
        elif u.kind in ("attn", "mla", "cross"):
            out[u.name] = _p1_attn(taps, u, cfg)
    return out


def pass2_reduce(taps: Dict, units: List[Unit], plan: Dict) -> Dict:
    out = {}
    for u in units:
        if u.kind in ("attn", "mla", "cross") and u.name in plan:
            keep, prune = plan[u.name]
            out[u.name] = _p2_attn(taps, u, keep, prune)
    return out


def make_stats_step(model, units: List[Unit], phase: int, plan=None):
    """Returns a jit-able fn(params, batch) -> stats pytree (sums)."""
    def step(params, batch):
        taps = {}
        model.apply(params, batch, taps=taps)
        if phase == 1:
            return pass1_reduce(taps, units, model.cfg)
        return pass2_reduce(taps, units, plan)
    return step


def tree_add(a, b):
    if a is None:
        return b
    return jax.tree.map(jnp.add, a, b)
