"""Prunable-unit discovery.

CORP operates on two kinds of structured units (paper §3.2) plus two
framework extensions:

  mlp      - hidden channels between the two MLP matrices (Alg. 2/3)
  attn     - per-head Q/K dimensions (Alg. 4/5); 'mla' prunes the nope block;
             'cross' covers enc-dec cross attention
  moe      - per-expert MLP hidden channels (expert-conditional statistics)
  rwkv_mlp - RWKV channel-mix hidden channels (structurally an MLP)
  mamba    - Mamba inner channels (beyond-paper; see DESIGN.md)

Compensator classes for attention (DESIGN.md §2.2 / repro.core.solve):
  1 full M (SVD fold)            - no rope, no qk-norm (paper-faithful)
  2 diag-complex per rotary pair - rope, no qk-norm
  3 diag-real per rotary pair    - rope + qk-norm (folds into norm scales)
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Unit:
    name: str             # "seg0/p0/mlp" etc. (diagnostic)
    seg: str              # segment param key: "seg0" | "enc" | "dec"
    layer_key: str        # "p0" | "l3"
    stacked: bool
    reps: int
    kind: str             # mlp | moe | rwkv_mlp | mamba | attn | mla | cross
    tap_prefix: str       # tap key prefix "seg0/p0"
    # attention metadata
    attn_class: int = 1
    n_groups: int = 1     # kv heads (M solved per group)
    q_per_group: int = 1
    # mlp metadata
    d_hidden: int = 0     # full hidden dim (per expert for moe)
    param_key: str = "mlp"  # block sub-key holding the unit's params
    shared_expert: bool = False


def attn_class(cfg: ModelConfig, kind: str) -> int:
    if kind in ("mla", "cross"):
        return 1
    uses_rope = cfg.family == "lm" and cfg.rwkv is None and cfg.mla is None
    if not uses_rope:
        return 1
    return 3 if cfg.qk_norm else 2


def discover_units(cfg: ModelConfig) -> List[Unit]:
    units: List[Unit] = []

    def block_units(seg, lk, stacked, reps, kind, is_moe, prefix,
                    cross=False):
        # mixer unit
        if kind in ("attn", "swa"):
            if cfg.mla is not None:
                units.append(Unit(f"{prefix}/mla", seg, lk, stacked, reps,
                                  "mla", prefix, attn_class=1,
                                  n_groups=cfg.n_heads, q_per_group=1,
                                  param_key="mixer"))
            else:
                units.append(Unit(f"{prefix}/attn", seg, lk, stacked, reps,
                                  "attn", prefix,
                                  attn_class=attn_class(cfg, kind),
                                  n_groups=cfg.n_kv_heads,
                                  q_per_group=cfg.q_per_kv,
                                  param_key="mixer"))
        elif kind == "mamba":
            units.append(Unit(f"{prefix}/mamba", seg, lk, stacked, reps,
                              "mamba", prefix,
                              d_hidden=cfg.mamba.expand * cfg.d_model,
                              param_key="mixer"))
        if cross:
            units.append(Unit(f"{prefix}/cross", seg, lk, stacked, reps,
                              "cross", prefix, attn_class=1,
                              n_groups=cfg.n_kv_heads,
                              q_per_group=cfg.q_per_kv, param_key="cross"))
        # mlp unit
        if kind == "rwkv":
            units.append(Unit(f"{prefix}/rwkv_mlp", seg, lk, stacked, reps,
                              "rwkv_mlp", prefix, d_hidden=cfg.d_ff,
                              param_key="mlp"))
        elif is_moe:
            units.append(Unit(f"{prefix}/moe", seg, lk, stacked, reps,
                              "moe", prefix, d_hidden=cfg.moe.d_expert,
                              param_key="mlp"))
            if cfg.moe.num_shared > 0:
                units.append(Unit(f"{prefix}/shared", seg, lk, stacked, reps,
                                  "mlp", prefix,
                                  d_hidden=cfg.moe.num_shared
                                  * cfg.moe.d_expert,
                                  param_key="mlp", shared_expert=True))
        else:
            dff = cfg.d_ff
            if cfg.moe is not None and cfg.dense_d_ff:
                dff = cfg.dense_d_ff
            units.append(Unit(f"{prefix}/mlp", seg, lk, stacked, reps,
                              "mlp", prefix, d_hidden=dff, param_key="mlp"))

    if cfg.family == "vit":
        block_units("seg0", "p0", True, cfg.n_layers, "attn", False,
                    "seg0/p0")
        return units
    if cfg.family == "encdec":
        block_units("enc", "p0", True, cfg.n_enc_layers, "attn", False,
                    "enc/p0")
        block_units("dec", "p0", True, cfg.n_layers, "attn", False,
                    "dec/p0", cross=True)
        return units
    # lm
    for si, seg in enumerate(cfg.layout()):
        name = f"seg{si}"
        if seg[0] == "unroll":
            for j, li in enumerate(seg[1]):
                kind, moe = cfg.layer_spec(li)
                block_units(name, f"l{j}", False, 1, kind, moe,
                            f"{name}/l{j}")
        else:
            _, reps, idxs = seg
            for j, li in enumerate(idxs):
                kind, moe = cfg.layer_spec(li)
                block_units(name, f"p{j}", True, reps, kind, moe,
                            f"{name}/p{j}")
    return units


def get_block(params, unit: Unit):
    return params[unit.seg][unit.layer_key][unit.param_key]


def set_block(params, unit: Unit, value):
    params[unit.seg][unit.layer_key] = dict(
        params[unit.seg][unit.layer_key], **{unit.param_key: value})
