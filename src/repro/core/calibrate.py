"""Single-pass fused calibration engine for CORP (Alg. 3 inputs).

CORP's entire cost is the calibration pass, so how statistics stream out of
activations decides whether the paper's "under 20 minutes on one device"
claim holds. ``CalibrationEngine`` owns that hot path:

  * **one forward per batch** — a single jitted step runs the model once and
    reduces *every* unit's statistics (MLP/MoE/mamba moments, attention
    logit energies for pass 1; ridge-system inputs for pass 2) from the
    taps of that one forward, instead of a per-unit loop of separately
    jitted steps that each re-run the model;
  * **donated on-device accumulator** — the statistics pytree stays on
    device across the whole pass and the previous accumulator's buffers are
    donated to each step (``donate_argnums=0``), so accumulation is
    in-place with no host round-trip per batch; only the final result is
    fetched;
  * **checkpointable** — the accumulator is an ordinary pytree of sums, so
    any prefix of the stream is a valid checkpoint. Pass a
    ``repro.distrib.fault.CalibrationCheckpointer`` to make a long pass
    resumable (batches are deterministic-by-index; the restored batch
    cursor skips what was already consumed);
  * **second moments through the Pallas gram kernel** — the per-unit
    ``X^T X`` reductions inside the step dispatch to
    ``repro.kernels.gram`` (streaming MXU kernel on TPU, zero-padded for
    arbitrary shapes; plain-jnp reference elsewhere).

Usage::

    engine = CalibrationEngine(model, units, phase=1)
    stats  = engine.run(params, calib_batches())            # pass 1
    engine2 = CalibrationEngine(model, units, phase=2, plan=plan)
    p2     = engine2.run(params, calib_batches())           # pass 2

Every statistic is a linear reduction, so under pjit the per-batch sums
compile to psums over the data axes and the engine distributes unchanged.
``benchmarks/bench_calibration.py`` records fused-vs-per-unit-loop
throughput.
"""
from __future__ import annotations

import hashlib
import itertools
from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stats as stats_mod
from repro.core.units import Unit


class CalibrationEngine:
    """Fused streaming statistics gatherer for one calibration pass.

    Args:
      model: model object exposing ``apply(params, batch, taps=...)``.
      units: prunable units whose statistics to gather (all in one forward).
      phase: 1 (ranking/MLP moments + attention energies) or 2 (attention
        compensation ridge inputs; requires ``plan``).
      plan: phase-2 only — ``{unit.name: (keep, prune)}`` index arrays.
      donate: donate the accumulator's buffers to each step (in-place
        accumulation). Disable when the caller needs the pre-step
        accumulator to survive a failing step (see ``fail_hook``).
    """

    def __init__(self, model, units: List[Unit], *, phase: int = 1,
                 plan: Optional[Dict] = None, donate: bool = True):
        assert phase in (1, 2), phase
        assert phase == 1 or plan is not None, "phase 2 needs a keep/prune plan"
        self.model = model
        self.units = list(units)
        self.phase = phase
        self.plan = None if plan is None else {
            k: tuple(jnp.asarray(a) for a in v) for k, v in plan.items()}

        def reduce_fn(params, batch):
            taps = {}
            model.apply(params, batch, taps=taps)
            if phase == 1:
                return stats_mod.pass1_reduce(taps, self.units, model.cfg)
            return stats_mod.pass2_reduce(taps, self.units, self.plan)

        def step(acc, params, batch):
            return jax.tree.map(jnp.add, acc, reduce_fn(params, batch))

        self._reduce = reduce_fn
        self._step = jax.jit(step, donate_argnums=(0,) if donate else ())
        self.fingerprint = self._fingerprint()

    def _fingerprint(self) -> str:
        """Identity of what this engine accumulates — phase, unit set, and
        (for pass 2) the exact keep/prune plan. Stored with every stats
        checkpoint so a reused checkpoint directory can never resume
        statistics gathered for a different configuration."""
        h = hashlib.sha256()
        h.update(f"phase={self.phase}".encode())
        for u in self.units:
            h.update(f";{u.name}:{u.kind}:{u.attn_class}".encode())
        if self.plan is not None:
            for k in sorted(self.plan):
                h.update(f";plan:{k}".encode())
                for a in self.plan[k]:
                    h.update(np.asarray(a).tobytes())
        return h.hexdigest()[:16]

    # -- accumulator lifecycle ------------------------------------------------

    def init_stats(self, params, batch):
        """Zeros pytree matching one batch's statistics (via eval_shape —
        no forward is executed)."""
        shapes = jax.eval_shape(self._reduce, params, batch)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def update(self, acc, params, batch):
        """One fused step: acc + stats(batch), on device. ``acc``'s buffers
        are donated — use the return value, not the argument."""
        return self._step(acc, params, batch)

    # -- driver ---------------------------------------------------------------

    def run(self, params, batches: Iterable, *, checkpointer=None,
            fail_hook: Optional[Callable[[int], None]] = None) -> Dict:
        """Stream ``batches`` through the fused step; returns host stats.

        checkpointer: optional ``fault.CalibrationCheckpointer`` — restores
          the newest valid stats checkpoint (skipping the already-consumed
          stream prefix) and saves the accumulator every N batches.
        fail_hook: optional ``hook(i)`` called before batch ``i``; if it
          raises, the batch is dropped and the pass continues (the
          bounded-staleness mode of ``repro.distrib.fault`` — statistics
          carry their own sample counts, so dropped batches only shrink n).
        """
        it = iter(batches)
        try:
            first = next(it)
        except StopIteration:
            raise ValueError("empty calibration stream") from None
        acc = self.init_stats(params, first)
        start = 0
        if checkpointer is not None:
            acc, start = checkpointer.restore(acc, self.fingerprint)
        n_seen = 0
        for i, batch in enumerate(itertools.chain([first], it)):
            if i < start:
                continue
            if fail_hook is not None:
                try:
                    fail_hook(i)
                except Exception:       # noqa: BLE001 — simulated host loss
                    continue
            acc = self._step(acc, params, batch)
            n_seen += 1
            if checkpointer is not None:
                checkpointer.maybe_save(acc, i + 1, self.fingerprint)
        if start == 0 and n_seen == 0:
            raise ValueError("every calibration batch failed")
        return jax.device_get(acc)


def run_pass(model, units: List[Unit], params, batches: Iterable, *,
             phase: int = 1, plan: Optional[Dict] = None,
             checkpointer=None) -> Dict:
    """One-call convenience wrapper: build an engine and run one pass."""
    eng = CalibrationEngine(model, units, phase=phase, plan=plan)
    return eng.run(params, batches, checkpointer=checkpointer)
