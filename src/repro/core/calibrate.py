"""Single-pass fused calibration engine for CORP (Alg. 3 inputs).

CORP's entire cost is the calibration pass, so how statistics stream out of
activations decides whether the paper's "under 20 minutes on one device"
claim holds. ``CalibrationEngine`` owns that hot path:

  * **one forward per batch** — a single jitted step runs the model once and
    reduces *every* unit's statistics (MLP/MoE/mamba moments, attention
    logit energies for pass 1; ridge-system inputs for pass 2) from the
    taps of that one forward, instead of a per-unit loop of separately
    jitted steps that each re-run the model;
  * **donated on-device accumulator** — the statistics pytree stays on
    device across the whole pass and the previous accumulator's buffers are
    donated to each step (``donate_argnums=0``), so accumulation is
    in-place with no host round-trip per batch; only the final result is
    fetched;
  * **checkpointable** — the accumulator is an ordinary pytree of sums, so
    any prefix of the stream is a valid checkpoint. Pass a
    ``repro.distrib.fault.CalibrationCheckpointer`` to make a long pass
    resumable (batches are deterministic-by-index; the restored batch
    cursor skips what was already consumed; saves run on a background
    thread so the pass never blocks on disk);
  * **one-traversal** — ``phase="1+2"`` accumulates pass-1 statistics AND
    speculative pass-2 cross-moments (against fixed top-k candidate
    keep-sets, ``spec_plan=``) from the *same* forward, so when the final
    keep-sets land inside the candidates — the common case — CORP needs no
    second traversal of the calibration set (``corp_prune(...,
    one_traversal=True)``; design + margin policy in docs/pipeline.md);
  * **second moments through the Pallas gram kernel** — the per-unit
    ``X^T X`` reductions inside the step dispatch to
    ``repro.kernels.gram`` (streaming MXU kernel on TPU, zero-padded for
    arbitrary shapes, tile sizes autotuned per shape; plain-jnp reference
    elsewhere);
  * **bf16 activation streaming** — ``stats_dtype="bfloat16"`` emits the
    model's activation taps in bf16 and streams them into the gram kernel
    as-is, halving calibration HBM traffic; every accumulator stays fp32
    (the kernel casts per tile inside VMEM). Sigma tolerance vs the fp32
    stream is gated in ``benchmarks/bench_calibration.py`` and studied in
    docs/kernels.md;
  * **mesh-sharded** — pass ``mesh=`` and the fused step runs under pjit
    with an explicit sharding for every statistic leaf
    (``repro.distrib.sharding.stats_specs``): per-unit covariance/Gram
    blocks are column-sharded over the mesh's model axis, batch-axis
    contributions reduce via psum inside the compiled step, and the dense
    second moments route through the *per-shard* Pallas gram path
    (``gram_sharded`` — zero-padding on local tiles). No device ever holds
    a replicated full Sigma, which is what lets a 671B-config calibration
    pass fit (one dense-FFN Sigma at d_ff=18432 is 1.3 GB fp32 replicated,
    but only 1.3/m GB per device on an m-way model axis). See
    docs/calibration.md for the layout diagram.

Usage::

    engine = CalibrationEngine(model, units, phase=1)
    stats  = engine.run(params, calib_batches())            # pass 1
    engine2 = CalibrationEngine(model, units, phase=2, plan=plan)
    p2     = engine2.run(params, calib_batches())           # pass 2

    # sharded: same API, statistics land model-sharded on the mesh
    mesh = repro.launch.mesh.make_mesh((2, 4))              # data x model
    stats = CalibrationEngine(model, units, phase=1, mesh=mesh) \\
        .run(params, calib_batches())

Every statistic is a linear reduction, so the sharded engine is bitwise a
partitioning of the single-device one (same sums, same order per shard);
``tests/test_sharded_calibration.py`` asserts fp32 parity on a forced
4-device host mesh. ``benchmarks/bench_calibration.py`` records
fused-vs-per-unit-loop throughput and ``benchmarks/bench_calib_sharded.py``
the sharded engine's per-device Sigma footprint.
"""
from __future__ import annotations

import hashlib
import itertools
from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stats as stats_mod
from repro.core.units import Unit
from repro.distrib import sharding as dist_sharding
from repro.models import common as model_common


class CalibrationEngine:
    """Fused streaming statistics gatherer for one calibration pass.

    Args:
      model: model object exposing ``apply(params, batch, taps=...)``.
      units: prunable units whose statistics to gather (all in one forward).
      phase: 1 (ranking/MLP moments + attention energies), 2 (attention
        compensation ridge inputs; requires ``plan``), or ``"1+2"``
        (one-traversal mode: pass-1 statistics plus *speculative* pass-2
        cross-moments against fixed candidate keep-sets; requires
        ``spec_plan``). The ``"1+2"`` accumulator is
        ``{"p1": <pass-1 tree>, "p2spec": <speculative tree>}`` — see
        ``repro.core.stats.spec_pass2_reduce`` / ``spec_reconstruct`` and
        docs/pipeline.md.
      plan: phase-2 only — ``{unit.name: (keep, prune)}`` index arrays.
      spec_plan: phase-"1+2" only — ``{unit.name: (..., G, c) candidate
        keep-indices}`` (``repro.core.ranking.candidate_attn``), fixed for
        the whole traversal.
      donate: donate the accumulator's buffers to each step (in-place
        accumulation). Disable when the caller needs the pre-step
        accumulator to survive a failing step (see ``fail_hook``).
      mesh: optional ``jax.sharding.Mesh`` (or a pre-built
        ``repro.distrib.sharding.CalibSharding``). When given, the fused
        step is jitted with ``stats_specs`` out-shardings: every per-unit
        covariance/Gram block is column-sharded over ``model_axis``, batch
        contributions psum-reduce over the data axes, and params/batches
        are placed per ``param_specs``/``batch_specs``. Statistics are
        numerically identical to the unsharded engine (linear reductions);
        only their device layout changes.
      model_axis: mesh axis name that partitions statistic columns
        (ignored without ``mesh``).
      stats_dtype: dtype activation taps are *streamed* in ("float32"
        default, "bfloat16" to halve calibration HBM traffic). Every
        statistic still accumulates in fp32 — the gram kernel casts tiles
        inside VMEM, the other reductions cast at their inputs — so only
        the per-tap rounding differs (docs/kernels.md quantifies the Sigma
        tolerance; benchmarks/bench_calibration.py gates it).

    Attributes:
      fingerprint: hash of what this engine accumulates (phase, unit set,
        pass-2 plan, and — when sharded — the mesh layout). Stored with
        every statistics checkpoint; see ``CalibrationCheckpointer``.
      stat_shardings: sharded mode only — the ``NamedSharding`` pytree of
        the accumulator, available after ``init_stats``/``run`` started
        (None before, and always None unsharded).
    """

    def __init__(self, model, units: List[Unit], *, phase=1,
                 plan: Optional[Dict] = None,
                 spec_plan: Optional[Dict] = None, donate: bool = True,
                 mesh=None, model_axis: str = "model",
                 stats_dtype="float32"):
        assert phase in (1, 2, "1+2"), phase
        assert phase != 2 or plan is not None, "phase 2 needs a keep/prune plan"
        assert phase != "1+2" or spec_plan is not None, \
            'phase "1+2" needs a speculative candidate plan'
        self.model = model
        self.units = list(units)
        self.phase = phase
        self.stats_dtype = jnp.dtype(stats_dtype)
        self.plan = None if plan is None else {
            k: tuple(jnp.asarray(a) for a in v) for k, v in plan.items()}
        self.spec_plan = None if spec_plan is None else {
            k: jnp.asarray(v) for k, v in spec_plan.items()}
        if mesh is None:
            self.shard = None
        elif isinstance(mesh, dist_sharding.CalibSharding):
            self.shard = mesh
        else:
            self.shard = dist_sharding.CalibSharding(mesh, model_axis)

        def reduce_fn(params, batch):
            taps = {}
            # entered at trace time: taps stream in stats_dtype end-to-end
            with model_common.tap_dtype(self.stats_dtype):
                model.apply(params, batch, taps=taps)
            if phase == 1:
                return stats_mod.pass1_reduce(taps, self.units, model.cfg,
                                              shard=self.shard)
            if phase == 2:
                return stats_mod.pass2_reduce(taps, self.units, self.plan)
            # "1+2": both reductions from the SAME forward's taps — the
            # one-traversal mode's whole point
            return {"p1": stats_mod.pass1_reduce(taps, self.units, model.cfg,
                                                 shard=self.shard),
                    "p2spec": stats_mod.spec_pass2_reduce(
                        taps, self.units, self.spec_plan)}

        def step(acc, params, batch):
            return jax.tree.map(jnp.add, acc, reduce_fn(params, batch))

        self._reduce = reduce_fn
        self._step_fn = step
        self._donate = donate
        self.stat_shardings = None
        self._batch_cache = None
        if self.shard is None:
            self._step = jax.jit(step, donate_argnums=(0,) if donate else ())
        else:
            self._step = None   # built by init_stats (needs stat shapes)
        self.fingerprint = self._fingerprint()

    def _fingerprint(self) -> str:
        """Identity of what this engine accumulates — phase, unit set,
        (for pass 2) the exact keep/prune plan, (for phase "1+2") the exact
        speculative candidate sets, and (when sharded) the mesh layout.
        Stored with every stats checkpoint so a reused checkpoint directory
        can never resume statistics gathered for a different configuration
        — including a checkpoint written under a *different mesh*, whose
        shard-local accumulation order (and donation layout) this engine
        cannot reproduce — or under a different streaming dtype, whose
        per-tap rounding differs. Phase "1+2" hashes differently from both
        1 and 2 (and per candidate set), so speculative checkpoints are
        rejected by two-pass engines and vice versa."""
        h = hashlib.sha256()
        h.update(f"phase={self.phase};stats_dtype={self.stats_dtype}"
                 .encode())
        for u in self.units:
            h.update(f";{u.name}:{u.kind}:{u.attn_class}".encode())
        if self.plan is not None:
            for k in sorted(self.plan):
                h.update(f";plan:{k}".encode())
                for a in self.plan[k]:
                    h.update(np.asarray(a).tobytes())
        if self.spec_plan is not None:
            for k in sorted(self.spec_plan):
                h.update(f";spec:{k}".encode())
                h.update(np.asarray(self.spec_plan[k]).tobytes())
        if self.shard is not None:
            mesh = self.shard.mesh
            h.update(f";mesh={tuple(mesh.axis_names)}"
                     f"x{tuple(mesh.devices.shape)}"
                     f":{self.shard.model_axis}".encode())
        return h.hexdigest()[:16]

    # -- accumulator lifecycle ------------------------------------------------

    def init_stats(self, params, batch):
        """Zeros pytree matching one batch's statistics (via eval_shape —
        no forward is executed).

        Unsharded: plain device zeros. Sharded: computes ``stats_specs``
        for the statistic shapes, builds the pjit-ed step with those
        out-shardings, and returns zeros already placed shard-by-shard
        (so the first donated step never reshards the accumulator).
        """
        shapes = jax.eval_shape(self._reduce, params, batch)
        if self.shard is None:
            return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        specs = dist_sharding.stats_specs(shapes, self.shard.mesh,
                                          model_axis=self.shard.model_axis)
        shardings = dist_sharding.shardings_of(specs, self.shard.mesh)
        # rebuild the jitted step only when the layout actually changed —
        # re-wrapping jax.jit would discard its compile cache, retracing
        # the whole model per run()/resume
        if self._step is None or shardings != self.stat_shardings:
            self.stat_shardings = shardings
            self._step = jax.jit(self._step_fn,
                                 donate_argnums=(0,) if self._donate else (),
                                 out_shardings=self.stat_shardings)
        return jax.tree.map(
            lambda s, sh: jax.device_put(jnp.zeros(s.shape, s.dtype), sh),
            shapes, self.stat_shardings)

    def update(self, acc, params, batch):
        """One fused step: acc + stats(batch), on device. ``acc``'s buffers
        are donated — use the return value, not the argument."""
        if self._step is None:
            raise RuntimeError(
                "sharded CalibrationEngine: call init_stats(params, batch) "
                "before update() so the stat shardings exist")
        return self._step(acc, params, batch)

    # -- sharded placement ----------------------------------------------------

    def _put_params(self, params):
        mesh = self.shard.mesh
        return jax.device_put(params, dist_sharding.shardings_of(
            dist_sharding.param_specs(params, mesh), mesh))

    def _put_batch(self, batch):
        """device_put per ``batch_specs``, caching the sharding pytree —
        calibration streams have constant shapes, so the per-batch spec
        walk would be pure hot-loop overhead."""
        key = (jax.tree.structure(batch),
               tuple(x.shape for x in jax.tree.leaves(batch)))
        if self._batch_cache is None or self._batch_cache[0] != key:
            mesh = self.shard.mesh
            self._batch_cache = (key, dist_sharding.shardings_of(
                dist_sharding.batch_specs(batch, mesh), mesh))
        return jax.device_put(batch, self._batch_cache[1])

    # -- driver ---------------------------------------------------------------

    def run(self, params, batches: Iterable, *, checkpointer=None,
            fail_hook: Optional[Callable[[int], None]] = None) -> Dict:
        """Stream ``batches`` through the fused step; returns host stats.

        Args:
          params: model parameters. In sharded mode they are device_put per
            ``param_specs`` once up front (the step then never reshards).
          batches: iterable of calibration batches (deterministic-by-index
            when resuming from a checkpoint).
          checkpointer: optional ``fault.CalibrationCheckpointer`` —
            restores the newest valid stats checkpoint (skipping the
            already-consumed stream prefix) and saves the accumulator every
            N batches (on a background thread by default — the pass never
            blocks on disk; ``run`` sync-flushes the in-flight save before
            returning). Sharded accumulators are gathered on save and
            re-placed shard-by-shard on restore (see fault.py for the
            trade-off).
          fail_hook: optional ``hook(i)`` called before batch ``i``; if it
            raises, the batch is dropped and the pass continues (the
            bounded-staleness mode of ``repro.distrib.fault`` — statistics
            carry their own sample counts, so dropped batches only
            shrink n).

        Returns:
          ``{unit.name: {stat: np-like}}`` — the summed statistics pytree,
          fetched to host (sharded accumulators are gathered).
        """
        it = iter(batches)
        try:
            first = next(it)
        except StopIteration:
            raise ValueError("empty calibration stream") from None
        if self.shard is not None:
            params = self._put_params(params)
        acc = self.init_stats(params, first)
        start = 0
        if checkpointer is not None:
            acc, start = checkpointer.restore(
                acc, self.fingerprint, shardings=self.stat_shardings)
        n_seen = 0
        for i, batch in enumerate(itertools.chain([first], it)):
            if i < start:
                continue
            if fail_hook is not None:
                try:
                    fail_hook(i)
                except Exception:       # noqa: BLE001 — simulated host loss
                    continue
            if self.shard is not None:
                batch = self._put_batch(batch)
            acc = self._step(acc, params, batch)
            n_seen += 1
            if checkpointer is not None:
                checkpointer.maybe_save(acc, i + 1, self.fingerprint)
        if start == 0 and n_seen == 0:
            raise ValueError("every calibration batch failed")
        if checkpointer is not None:
            # sync-flush: the newest checkpoint is durably on disk before
            # the pass reports completion (async saves run in background)
            checkpointer.finish()
        return jax.device_get(acc)


def run_pass(model, units: List[Unit], params, batches: Iterable, *,
             phase: int = 1, plan: Optional[Dict] = None,
             checkpointer=None, mesh=None, stats_dtype="float32") -> Dict:
    """One-call convenience wrapper: build an engine and run one pass."""
    eng = CalibrationEngine(model, units, phase=phase, plan=plan, mesh=mesh,
                            stats_dtype=stats_dtype)
    return eng.run(params, batches, checkpointer=checkpointer)
