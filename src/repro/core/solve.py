"""Closed-form CORP solvers (paper §3.4, App. B) and weight folds.

MLP affine compensation (Eq. 9):
    B = Sigma_PS (Sigma_SS + lam I)^-1,   c = mu_P - B mu_S

Attention logit compensation:
  class 1 (paper Eq. 15, no rope / no qk-norm):
    [ sum_b (K_S^T K_S) (x) (Q_S^T Q_S) + lam I ] vec(M) = sum_b vec((Q_S^T Q_P)(K_P^T K_S))
    fold I + M = U S V^T into W_Q U S^{1/2}, W_K V S^{1/2} (Eq. 16).
  class 2 (rope): M restricted to a diagonal *complex* per-rotary-pair
    compensator m (the only family that commutes with rotary phase), solved
    from the Hadamard-reduced normal equations
        (sum_b A_S (.) C_S^T + lam I) m = sum_b diag(A_SP C_PS),
    A = Q^H Q, C = K^H K over complex pairs; folded as per-pair 2x2
    rotation-scaling blocks a = sqrt(rho) e^{i phi/2} into W_Q and
    b = sqrt(rho) e^{-i phi/2} into W_K (a * conj(b) = 1 + m).
  class 3 (rope + qk-norm): real positive-diagonal restriction of class 2,
    folded into the qk-norm scale vectors.

All solvers return diagnostics: the closed-form distortion terms J* and the
compensation gain (paper Eqs. 11, 17, 64, 92) — available "for free" from the
same matrices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# MLP affine compensation
# ---------------------------------------------------------------------------

def mlp_cov(stats):
    """stats: {'n','s1','s2'} -> (mu, Sigma) fp64-free fp32."""
    n = jnp.maximum(stats["n"], 1.0)
    mu = stats["s1"] / n
    sigma = stats["s2"] / n - jnp.outer(mu, mu)
    return mu, sigma


def ridge_affine(mu, sigma, keep, prune, lam: float):
    """Closed-form (B, c) of Eq. 9 plus distortion diagnostics.

    keep/prune: int32 index arrays. Returns dict with B (|P|,|S|), c (|P|,),
    and the Schur residual Sigma_{P|S} needed for J*.
    """
    S_SS = sigma[jnp.ix_(keep, keep)]
    S_PS = sigma[jnp.ix_(prune, keep)]
    S_PP = sigma[jnp.ix_(prune, prune)]
    ds = keep.shape[0]
    reg = S_SS + lam * jnp.eye(ds, dtype=sigma.dtype)
    cho = jax.scipy.linalg.cho_factor(reg)
    B = jax.scipy.linalg.cho_solve(cho, S_PS.T).T          # (|P|, |S|)
    c = mu[prune] - B @ mu[keep]
    sigma_p_given_s = S_PP - B @ S_PS.T
    return {"B": B, "c": c, "mu_p": mu[prune],
            "sigma_pp": S_PP, "sigma_p_given_s": sigma_p_given_s}


def mlp_distortion(sol, w_p):
    """J* and gain (Eqs. 11/64). w_p: (|P|, D) pruned rows of the second
    matrix (output-major orientation: y = h @ W, W (F, D))."""
    wp = w_p.astype(jnp.float32)
    j_star = jnp.sum((sol["sigma_p_given_s"] @ wp) * wp)
    j_uncomp = jnp.sum((sol["sigma_pp"] @ wp) * wp) \
        + jnp.sum(jnp.square(sol["mu_p"] @ wp))
    return {"j_star": j_star, "j_uncomp": j_uncomp,
            "gain": j_uncomp - j_star}


# ---------------------------------------------------------------------------
# attention compensation
# ---------------------------------------------------------------------------

def solve_full_m(G, h, t2, lam: float):
    """Class 1: vec(M) = (G + lam I)^-1 h (row-major vec)."""
    d2 = G.shape[0]
    ds = int(round(d2 ** 0.5))
    reg = G + lam * jnp.eye(d2, dtype=G.dtype)
    cho = jax.scipy.linalg.cho_factor(reg)
    m = jax.scipy.linalg.cho_solve(cho, h)
    M = m.reshape(ds, ds)
    j_star = t2 - h @ m          # Eq. 17 at the ridge optimum (lam -> 0)
    return {"M": M, "j_star": j_star, "j_uncomp": t2,
            "rho2": jnp.where(t2 > 0, (h @ m) / t2, 0.0)}


def solve_diag_complex(Gd, hd, t2, lam: float):
    """Class 2: (Gd + lam I) m = hd over complex pairs."""
    dp = Gd.shape[0]
    m = jnp.linalg.solve(Gd + lam * jnp.eye(dp, dtype=Gd.dtype), hd)
    gain = jnp.real(jnp.vdot(hd, m))
    return {"m": m, "j_star": t2 - gain, "j_uncomp": t2,
            "rho2": jnp.where(t2 > 0, gain / t2, 0.0)}


def solve_diag_real(Gd, hd, t2, lam: float):
    """Class 3: real restriction (Gd, hd already real-reduced)."""
    dp = Gd.shape[0]
    m = jnp.linalg.solve(Gd + lam * jnp.eye(dp, dtype=Gd.dtype), hd)
    gain = hd @ m
    return {"m": m, "j_star": t2 - gain, "j_uncomp": t2,
            "rho2": jnp.where(t2 > 0, gain / t2, 0.0)}


# ---------------------------------------------------------------------------
# folds
# ---------------------------------------------------------------------------

def fold_full_m(M):
    """I + M = U S V^T -> (Fq, Fk) with Fq Fk^T = I + M (Eq. 16)."""
    ds = M.shape[0]
    u, s, vt = jnp.linalg.svd(jnp.eye(ds, dtype=M.dtype) + M)
    sq = jnp.sqrt(s)
    return u * sq[None, :], vt.T * sq[None, :]


def fold_diag_complex(m):
    """1 + m = rho e^{i phi}; a = sqrt(rho) e^{i phi/2}, b = conj-phase.

    Returns per-pair 2x2 real blocks (dp, 2, 2) for Q and K: right-
    multiplication on the (even, odd) columns of each kept rotary pair.
    """
    w = 1.0 + m
    rho = jnp.abs(w)
    phi = jnp.angle(w)
    a = jnp.sqrt(rho) * jnp.exp(1j * phi / 2.0)
    b = jnp.sqrt(rho) * jnp.exp(-1j * phi / 2.0)

    def blocks(z):
        re, im = jnp.real(z), jnp.imag(z)
        # complex right-multiplication as 2x2 acting on (x, y) row vectors
        return jnp.stack([jnp.stack([re, im], -1),
                          jnp.stack([-im, re], -1)], -2)
    return blocks(a), blocks(b)


def fold_diag_real(m):
    """1 + m real: per-pair scale sqrt|1+m| with sign assigned to Q side."""
    w = 1.0 + m
    s = jnp.sqrt(jnp.abs(w))
    return jnp.sign(w) * s, s


# ---------------------------------------------------------------------------
# index utilities
# ---------------------------------------------------------------------------

def pairs_to_dims(pair_idx):
    """rotary pair indices (..., p) -> interleaved dim indices (..., 2p)."""
    even = 2 * pair_idx
    odd = even + 1
    return jnp.stack([even, odd], axis=-1).reshape(
        pair_idx.shape[:-1] + (2 * pair_idx.shape[-1],))
