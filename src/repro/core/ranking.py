"""Ranking policies (paper §3.3, Alg. 2/4, appendix E).

MLP channels:
  'act'      E_i = E[x_i^2]                 (activation energy)
  'mag'      ||W_{:,i}||_2                  (second-matrix column norm)
  'combined' E_i * ||W_{:,i}||_2            (default — best in the paper)
  'active'   P(|x_i| > eps)                 (activation frequency)

Attention head dims (per kv group): logit energy s_j = E[||q_j||^2 ||k_j||^2]
(accumulated in pass 1; complex-pair energies for rope archs).

Selection returns sorted kept/pruned index arrays; all scores are reduced on
host (numpy) — they are tiny compared to the statistics themselves.
"""
from __future__ import annotations

import numpy as np

POLICIES = ("act", "mag", "combined", "active")


def _select(scores: np.ndarray, keep_n: int):
    """scores: (..., F) -> kept (..., keep_n), pruned (..., F-keep_n), sorted."""
    order = np.argsort(-scores, axis=-1, kind="stable")
    keep = np.sort(order[..., :keep_n], axis=-1)
    prune = np.sort(order[..., keep_n:], axis=-1)
    return keep.astype(np.int32), prune.astype(np.int32)


def mlp_scores(stats, w2, policy: str = "combined") -> np.ndarray:
    """stats: pass-1 moments (possibly stacked / per-expert); w2: matching
    second-matrix array with orientation (..., F, D)."""
    n = np.maximum(np.asarray(stats["n"], np.float64), 1.0)
    e = np.einsum("...ff->...f", np.asarray(stats["s2"], np.float64))
    e = e / n[..., None]
    if policy == "act":
        return e
    col = np.linalg.norm(np.asarray(w2, np.float64), axis=-1)   # (..., F)
    if policy == "mag":
        return col
    if policy == "combined":
        return e * col
    if policy == "active":
        return np.asarray(stats["na"], np.float64) / n[..., None]
    raise ValueError(policy)


def rank_mlp(stats, w2, keep_n: int, policy: str = "combined"):
    return _select(mlp_scores(stats, w2, policy), keep_n)


def rank_attn(stats, keep_n: int):
    """stats['rank']: (..., G, d or d/2 pairs) energy products."""
    return _select(np.asarray(stats["rank"], np.float64), keep_n)


def expert_scores(stats) -> np.ndarray:
    """Per-expert contribution energy from pass-1 moments.

    ``stats['ys2']`` is the (..., (E+1)D, (E+1)D) second moment of the MoE
    block input concatenated with the gate-weighted expert contributions
    (repro.core.stats._p1_moe); the trace of expert e's diagonal block is
    ``E[||c_te||^2]`` — how much of the MoE output's energy that expert
    carries under the calibration distribution. Block 0 (the input) is
    skipped.
    """
    n = np.maximum(np.asarray(stats["yn"], np.float64), 1.0)
    s2 = np.asarray(stats["ys2"], np.float64)
    e_num = np.asarray(stats["n"], np.float64).shape[-1]   # (..., E) counts
    diag = np.einsum("...ii->...i", s2)                     # (..., (E+1)D)
    per = diag.reshape(diag.shape[:-1] + (e_num + 1, -1)).sum(-1)
    return per[..., 1:] / n[..., None]


def rank_experts(stats, keep_n: int):
    """Kept/pruned routed-expert indices by contribution energy."""
    return _select(expert_scores(stats), keep_n)


# ---------------------------------------------------------------------------
# speculative candidate selection (one-traversal calibration)
# ---------------------------------------------------------------------------

def candidate_count(full: int, keep_n: int, margin: float) -> int:
    """Candidate keep-set size for speculative pass-2 accumulation:
    ``keep_n`` final slots plus a safety margin, clipped to the unit width.

    The margin buys hit-rate: the final keep-set is chosen from the *full*
    calibration set's ranking scores, while candidates are chosen from the
    running scores of the stream prefix — the top-``keep_n`` sets differ
    wherever scores are close, and the extra ``keep_n * margin`` slots
    absorb that churn (docs/pipeline.md quantifies margin vs hit-rate)."""
    assert margin >= 0.0, margin
    c = int(np.ceil(keep_n * (1.0 + margin)))
    return max(keep_n, min(full, c))


def candidate_attn(stats, keep_n: int, margin: float) -> np.ndarray:
    """Top-k candidate keep-set per kv group from *running* ranking scores.

    stats['rank']: (..., G, d or pairs) energy sums accumulated so far
    (any stream prefix — the scores only need to get the top-k set right,
    not converged values). Returns sorted int32 candidate indices
    (..., G, c) with ``c = candidate_count(full, keep_n, margin)``, a
    superset-in-expectation of the final ``rank_attn`` keep-set."""
    scores = np.asarray(stats["rank"], np.float64)
    c = candidate_count(scores.shape[-1], keep_n, margin)
    order = np.argsort(-scores, axis=-1, kind="stable")
    return np.sort(order[..., :c], axis=-1).astype(np.int32)


def covers(cand: np.ndarray, keep: np.ndarray) -> bool:
    """True iff every group's final keep-set is inside its candidate set —
    the speculative *hit* condition. cand: (..., G, c), keep: (..., G, n),
    matching leading dims, both index arrays."""
    c2 = np.asarray(cand).reshape(-1, cand.shape[-1])
    k2 = np.asarray(keep).reshape(-1, keep.shape[-1])
    assert c2.shape[0] == k2.shape[0], (cand.shape, keep.shape)
    return all(bool(np.isin(k, c).all()) for c, k in zip(c2, k2))
