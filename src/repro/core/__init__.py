"""CORP: closed-form one-shot representation-preserving structured pruning.

The paper's primary contribution as a composable JAX module:
  stats     - distributed streaming calibration statistics (psum-reducible)
  calibrate - fused single-forward CalibrationEngine (donated accumulator,
              Pallas gram second moments, checkpointable stat pytrees)
  ranking   - activation/magnitude/combined/active + logit-energy policies
  solve     - closed-form ridge solvers (affine, Kronecker, rope-aware) + folds
  pruner    - Alg. 1 orchestration: calibrate -> rank -> compensate -> fold
  units     - prunable-structure discovery across all model families
"""
from repro.core.calibrate import CalibrationEngine
from repro.core.pruner import (PruneConfig, corp_prune,
                               corp_prune_streamed)
from repro.core.units import Unit, discover_units

__all__ = ["CalibrationEngine", "PruneConfig", "corp_prune",
           "corp_prune_streamed", "Unit", "discover_units"]
