"""CORP pipeline (paper Alg. 1): calibrate -> rank -> compensate -> fold.

``corp_prune(model, params, calib_batches, cfg=PruneConfig(...))`` returns
``(pruned_params, pruned_config, report)``. The pruned model is a physically
smaller standard model (reduced d_ff / per-head qk dims) built by the same
model code — zero inference overhead (paper §1).

Statistics are gathered by the fused ``repro.core.calibrate
.CalibrationEngine``: one jitted, donated-accumulator step per calibration
batch reduces every unit's statistics from a single forward. Under pjit on
a mesh the per-batch reductions compile to psums over the data axes
(DESIGN.md §2.1), and the accumulator pytree can be checkpointed between
batches (``ckpt_dir=`` — fault tolerance for long calibration passes, see
repro.distrib.fault.CalibrationCheckpointer).

``one_traversal=True`` fuses the two calibration passes into one: during
pass 1 the engine speculatively accumulates pass-2 ridge statistics against
top-k candidate keep-sets (sized ``keep_n * (1 + spec_margin)`` from the
first batch's running scores); a final keep-set inside the candidates — the
common case — reconstructs (G, h, t2) exactly with zero extra traversals,
and the rare escape falls back to one targeted mini pass 2. Design, margin
policy, memory bound, and hit-rate study: docs/pipeline.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate as calib_mod
from repro.core import ranking as rank_mod
from repro.core import solve as solve_mod
from repro.core import stats as stats_mod
from repro.core.units import Unit, discover_units, get_block, set_block


@dataclasses.dataclass(frozen=True)
class PruneConfig:
    mlp_sparsity: float = 0.5
    attn_sparsity: float = 0.5
    expert_sparsity: float = 0.0  # whole routed experts removed (beyond-paper)
    lam: float = 1e-4            # ridge, relative to mean diagonal
    rank_policy: str = "combined"
    compensate: bool = True      # False = rank-only baseline (paper ablation)
    include_mamba: bool = True   # beyond-paper mamba inner-channel pruning
    round_to: int = 1            # TPU lane alignment (beyond-paper perf mode)
    seed: int = 0


def _keep_count(full: int, sparsity: float, round_to: int) -> int:
    k = int(round(full * (1.0 - sparsity)))
    if round_to > 1:
        k = max(round_to, (k // round_to) * round_to)
    return max(1, min(full, k))


def _attn_keep_n(u: Unit, full: int, pc: PruneConfig) -> int:
    """Kept dims (cls 1) / rotary pairs (cls 2/3) for an attention unit."""
    rt = pc.round_to if u.attn_class == 1 else max(1, pc.round_to // 2)
    return _keep_count(full, pc.attn_sparsity, rt)


_ATTN_KINDS = ("attn", "mla", "cross")


# ---------------------------------------------------------------------------
# statistics accumulation
# ---------------------------------------------------------------------------

def accumulate(step_fn: Callable, params, batches: Iterable) -> Dict:
    """Legacy host-side accumulation loop (one jitted step, tree-add on the
    host per batch). The pipeline itself uses CalibrationEngine's fused
    donated-accumulator step; this stays as the reference implementation
    for parity tests and the loop-vs-fused benchmark
    (benchmarks/bench_calibration.py)."""
    total = None
    jit_step = jax.jit(step_fn)
    for batch in batches:
        total = stats_mod.tree_add(total, jit_step(params, batch))
    assert total is not None, "empty calibration stream"
    return jax.device_get(total)


def _checkpointer(ckpt_dir: Optional[str], tag: str, every: int):
    if ckpt_dir is None:
        return None
    from repro.distrib.fault import CalibrationCheckpointer
    return CalibrationCheckpointer(f"{ckpt_dir}/{tag}", every=every)


# ---------------------------------------------------------------------------
# one-traversal speculative calibration (docs/pipeline.md)
# ---------------------------------------------------------------------------

def _speculative_pass(model, units, params, batches, pc: PruneConfig, *,
                      spec_margin: float, mesh, stats_dtype,
                      ckpt_dir=None, ckpt_every: int = 8):
    """Single traversal gathering pass-1 AND speculative pass-2 statistics.

    The candidate keep-sets are chosen from the *first batch's* ranking
    scores (one extra forward of that batch — not an extra traversal of
    the stream), sized ``keep_n * (1 + spec_margin)`` per unit; the fused
    ``phase="1+2"`` engine then streams the whole set once. Returns
    ``(p1, spec_plan, spec_stats)``.
    """
    import itertools as _it
    it = iter(batches)
    try:
        first = next(it)
    except StopIteration:
        raise ValueError("empty calibration stream") from None
    # the selector only needs the attention logit-energy scores — don't
    # compute (and discard) every dense unit's FxF moments for one batch
    attn_units = [u for u in units if u.kind in _ATTN_KINDS]
    selector = calib_mod.CalibrationEngine(model, attn_units, phase=1,
                                           mesh=mesh,
                                           stats_dtype=stats_dtype)
    s0 = selector.run(params, [first])
    spec_plan = {}
    for u in units:
        if u.kind in _ATTN_KINDS:
            full = s0[u.name]["rank"].shape[-1]
            spec_plan[u.name] = rank_mod.candidate_attn(
                s0[u.name], _attn_keep_n(u, full, pc), spec_margin)
    engine = calib_mod.CalibrationEngine(model, units, phase="1+2",
                                         spec_plan=spec_plan, mesh=mesh,
                                         stats_dtype=stats_dtype)
    combined = engine.run(params, _it.chain([first], it),
                          checkpointer=_checkpointer(ckpt_dir, "pass12",
                                                     ckpt_every))
    return combined["p1"], spec_plan, combined["p2spec"]


def _resolve_attn_pass2(model, units, params, calib_batches, attn_plan,
                        spec_plan, spec_stats, *, mesh, stats_dtype,
                        ckpt_dir=None, ckpt_every: int = 8, say=None):
    """Pass-2 statistics for every unit in ``attn_plan``.

    Speculative mode (``spec_plan`` not None): units whose final keep-set
    fell inside their candidate set reconstruct (G, h, t2) from the
    speculative accumulators — zero additional traversals; units that
    escaped fall back to ONE targeted mini pass 2 reducing only their
    statistics. Two-pass mode (``spec_plan`` None): the classic full
    pass 2. Returns ``(p2, misses)``.
    """
    say = say or (lambda s: None)
    p2, misses = {}, []
    if spec_plan is not None:
        for u in units:
            if u.name not in attn_plan:
                continue
            keep = np.asarray(attn_plan[u.name][0])
            if rank_mod.covers(spec_plan[u.name], keep):
                p2[u.name] = stats_mod.spec_reconstruct(
                    spec_stats[u.name], spec_plan[u.name], keep, u)
            else:
                misses.append(u.name)
        todo = {k: attn_plan[k] for k in misses}
        if todo:
            say(f"pass 2 (targeted): {len(todo)} unit(s) escaped the "
                f"speculative candidates")
    else:
        todo = attn_plan
        if todo:
            say("pass 2: attention compensation statistics")
    if todo:
        engine2 = calib_mod.CalibrationEngine(model, units, phase=2,
                                              plan=todo, mesh=mesh,
                                              stats_dtype=stats_dtype)
        p2.update(engine2.run(params, calib_batches(),
                              checkpointer=_checkpointer(ckpt_dir, "pass2",
                                                         ckpt_every)))
    return p2, misses


# ---------------------------------------------------------------------------
# per-unit folding
# ---------------------------------------------------------------------------

def _gather(a, idx, axis):
    """take_along_axis with idx's leading dims aligned to a's outermost."""
    idx = jnp.asarray(idx)
    shape = [1] * a.ndim
    lead = idx.ndim - 1
    for i in range(lead):
        shape[i] = idx.shape[i]
    shape[axis] = idx.shape[-1]
    return jnp.take_along_axis(a, idx.reshape(shape), axis=axis)


def _fold_mlp_block(p, stats, unit: Unit, pc: PruneConfig, keep, prune,
                    report):
    """Dense MLP (plain/glu) or rwkv channel-mix. keep/prune: (..., n)."""
    w1_keys = [k for k in ("wu", "wg", "wk") if k in p]
    w2_key = "wv" if unit.kind == "rwkv_mlp" else "wd"
    w2 = p[w2_key]                       # (..., F, D)
    new = dict(p)
    keep_j = jnp.asarray(keep)
    prune_j = jnp.asarray(prune)

    def solve_one(mu_sigma, keep, prune, w2):
        mu, sigma = mu_sigma
        lam = pc.lam * jnp.mean(jnp.diagonal(sigma, axis1=-2, axis2=-1))
        sol = solve_mod.ridge_affine(mu, sigma, keep, prune, lam)
        diag = solve_mod.mlp_distortion(sol, w2[prune].astype(jnp.float32))
        return sol["B"], sol["c"], diag

    mu, sigma = jax.vmap(solve_mod.mlp_cov)(stats) if keep_j.ndim > 1 \
        else solve_mod.mlp_cov(stats)
    if keep_j.ndim == 1:
        B, c, diag = solve_one((mu, sigma), keep_j, prune_j, w2)
        w2_S = w2[keep_j]
        w2_P = w2[prune_j]
        comp = jnp.einsum("ps,pd->sd", B, w2_P)
        bias = c @ w2_P
    else:
        flat_ms = (mu.reshape((-1,) + mu.shape[-1:]),
                   sigma.reshape((-1,) + sigma.shape[-2:]))
        kf = keep_j.reshape(-1, keep_j.shape[-1])
        pf = prune_j.reshape(-1, prune_j.shape[-1])
        w2f = w2.reshape((-1,) + w2.shape[-2:])
        B, c, diag = jax.vmap(solve_one)((flat_ms), kf, pf, w2f)
        w2_S = jnp.take_along_axis(w2f, kf[..., None], axis=1)
        w2_P = jnp.take_along_axis(w2f, pf[..., None], axis=1)
        comp = jnp.einsum("rps,rpd->rsd", B, w2_P)
        bias = jnp.einsum("rp,rpd->rd", c, w2_P)
        lead = w2.shape[:-2]
        w2_S = w2_S.reshape(lead + w2_S.shape[-2:])
        comp = comp.reshape(lead + comp.shape[-2:])
        bias = bias.reshape(lead + bias.shape[-1:])
        diag = jax.tree.map(lambda a: a.reshape(lead), diag)

    if pc.compensate:
        new[w2_key] = (w2_S.astype(jnp.float32) + comp).astype(w2.dtype)
        if unit.kind == "rwkv_mlp":
            # fold bias into a dedicated additive term applied before gating
            new["bv_comp"] = bias
        else:
            old_b = p.get("bd", jnp.zeros(bias.shape, jnp.float32))
            new["bd"] = (old_b.astype(jnp.float32) + bias)
    else:
        new[w2_key] = w2_S

    for k1 in w1_keys:
        new[k1] = _gather(p[k1], keep_j, axis=p[k1].ndim - 1)
    for bk in ("bu", "bg"):
        if bk in p:
            new[bk] = _gather(p[bk], keep_j, axis=p[bk].ndim - 1)
    report[unit.name] = jax.device_get(diag)
    return new


def _fold_moe_block(p, stats, unit: Unit, pc: PruneConfig, keep, prune,
                    report):
    """MoE experts: weights (..., E, D, F)/(..., E, F, D); per-expert stats."""
    new = dict(p)
    keep_j = jnp.asarray(keep)           # (..., E, ds)
    prune_j = jnp.asarray(prune)
    w2 = p["wd"]                          # (..., E, F, D)
    lead_shape = w2.shape[:-2]
    w2f = w2.reshape((-1,) + w2.shape[-2:])
    kf = keep_j.reshape(-1, keep_j.shape[-1])
    pf = prune_j.reshape(-1, prune_j.shape[-1])
    muf = np.asarray(stats["s1"], np.float64)
    nf = np.maximum(np.asarray(stats["n"], np.float64), 1.0)[..., None]
    mu = jnp.asarray((muf / nf).reshape(-1, muf.shape[-1]), jnp.float32)
    s2 = np.asarray(stats["s2"], np.float64) / nf[..., None]
    sigma = s2 - (muf / nf)[..., :, None] * (muf / nf)[..., None, :]
    sigma = jnp.asarray(sigma.reshape((-1,) + sigma.shape[-2:]), jnp.float32)

    def solve_one(mu, sigma, keep, prune, w2):
        lam = pc.lam * jnp.mean(jnp.diagonal(sigma, axis1=-2, axis2=-1))
        sol = solve_mod.ridge_affine(mu, sigma, keep, prune, lam)
        diag = solve_mod.mlp_distortion(sol, w2[prune].astype(jnp.float32))
        return sol["B"], sol["c"], diag

    B, c, diag = jax.vmap(solve_one)(mu, sigma, kf, pf, w2f)
    w2_S = jnp.take_along_axis(w2f, kf[..., None], axis=1)
    w2_P = jnp.take_along_axis(w2f, pf[..., None], axis=1)
    if pc.compensate:
        comp = jnp.einsum("rps,rpd->rsd", B, w2_P)
        new["wd"] = (w2_S.astype(jnp.float32) + comp).astype(w2.dtype) \
            .reshape(lead_shape + (kf.shape[-1], w2.shape[-1]))
        new["bd_moe"] = jnp.einsum("rp,rpd->rd", c, w2_P) \
            .reshape(lead_shape + (w2.shape[-1],))
    else:
        new["wd"] = w2_S.reshape(lead_shape + (kf.shape[-1], w2.shape[-1]))
    for k1 in ("wu", "wg"):
        new[k1] = _gather(p[k1], keep_j, axis=p[k1].ndim - 1)
    report[unit.name] = jax.device_get(
        jax.tree.map(lambda a: a.reshape(lead_shape), diag))
    return new


def _fold_moe_experts(p, stats, unit: Unit, pc: PruneConfig, keep, prune,
                      report):
    """Whole-expert removal (beyond-paper MoE extension of Eq. 9).

    The regression vector is the MoE block input concatenated with the
    gate-weighted expert contributions ``z_t = [x_t, c_t1..c_tE]``
    (moments yn/ys1/ys2 from ``repro.core.stats._p1_moe``): removed
    experts' contribution blocks are ridge-regressed onto the *input*
    block. Regressing on x rather than on the retained contributions is
    deliberate — after removal the router renormalizes its gate mass onto
    the surviving experts, shifting the retained-contribution distribution
    away from calibration (a fit against them measurably hurts); the input
    distribution at this block is routing-invariant. The summed solution
    folds into a dense residual map ``moe_resid`` (D, D) and bias
    ``moe_out_b`` applied after combine (repro.models.mlp.apply_moe);
    retained experts' weights are gathered untouched. keep/prune:
    (..., n) expert index arrays. Runs AFTER the hidden-channel fold.
    """
    new = dict(p)
    wd = p["wd"]                          # (..., E, F, D)
    lead = wd.shape[:-3]
    E, F, D = wd.shape[-3:]
    keep_j = jnp.asarray(keep, jnp.int32)
    prune_j = jnp.asarray(prune, jnp.int32)
    nP = prune_j.shape[-1]
    pf = prune_j.reshape(-1, nP)
    R = pf.shape[0]
    V = (E + 1) * D
    yn = jnp.maximum(jnp.asarray(stats["yn"], jnp.float32).reshape(R), 1.0)
    ys1 = jnp.asarray(stats["ys1"], jnp.float32).reshape(R, V)
    ys2 = jnp.asarray(stats["ys2"], jnp.float32).reshape(R, V, V)
    ar = jnp.arange(D, dtype=jnp.int32)
    idx_s = jnp.broadcast_to(ar, (R, D))                   # input block
    idx_p = ((pf + 1)[..., None] * D + ar).reshape(R, nP * D)

    def solve_one(n, s1, s2, i_s, i_p):
        mu = s1 / n
        sigma = s2 / n - jnp.outer(mu, mu)
        lam = pc.lam * jnp.mean(jnp.diagonal(sigma))
        sol = solve_mod.ridge_affine(mu, sigma, i_s, i_p, lam)
        # removed contributions enter the output through identity
        # (y = sum_e c_te) -> w_P is stacked identity blocks
        w_p = jnp.tile(jnp.eye(D, dtype=jnp.float32), (nP, 1))
        diag = solve_mod.mlp_distortion(sol, w_p)
        w = jnp.sum(sol["B"].reshape(nP, D, D), axis=0)    # x -> sum_r c_r
        b0 = jnp.sum(sol["c"].reshape(nP, D), axis=0)
        return w.T, b0, diag                               # y += x @ w.T

    W, b0, diag = jax.vmap(solve_one)(yn, ys1, ys2, idx_s, idx_p)
    if pc.compensate:
        new["moe_resid"] = W.reshape(lead + (D, D))
        new["moe_out_b"] = b0.reshape(lead + (D,))
    new["router"] = _gather(p["router"], keep_j, axis=p["router"].ndim - 1)
    for k1 in ("wu", "wg", "wd"):
        new[k1] = _gather(p[k1], keep_j, axis=p[k1].ndim - 3)
    if "bd_moe" in p:
        new["bd_moe"] = _gather(p["bd_moe"], keep_j,
                                axis=p["bd_moe"].ndim - 2)
    report[unit.name + "/experts"] = jax.device_get(
        jax.tree.map(lambda x: x.reshape(lead), diag))
    return new


def _moe_expert_plan(units, p1, cfg, pc: PruneConfig):
    """keep/prune expert index arrays per routed-MoE unit, or {}."""
    if pc.expert_sparsity <= 0 or cfg.moe is None:
        return {}
    keep_n = max(cfg.moe.top_k,
                 _keep_count(cfg.moe.num_experts, pc.expert_sparsity, 1))
    if keep_n >= cfg.moe.num_experts:
        return {}
    return {u.name: rank_mod.rank_experts(p1[u.name], keep_n)
            for u in units if u.kind == "moe"}


def _fold_mamba_block(p, stats, unit: Unit, pc: PruneConfig, keep, prune,
                      report):
    new = dict(p)
    keep_j = jnp.asarray(keep)
    prune_j = jnp.asarray(prune)
    di = p["d_skip"].shape[-1]
    out = p["out_proj"]                   # (..., di, D)

    def solve_one(mu_sigma, keep, prune, w2):
        mu, sigma = mu_sigma
        lam = pc.lam * jnp.mean(jnp.diagonal(sigma, axis1=-2, axis2=-1))
        sol = solve_mod.ridge_affine(mu, sigma, keep, prune, lam)
        diag = solve_mod.mlp_distortion(sol, w2[prune].astype(jnp.float32))
        return sol["B"], sol["c"], diag

    if keep_j.ndim == 1:
        mu, sigma = solve_mod.mlp_cov(stats)
        B, c, diag = solve_one((mu, sigma), keep_j, prune_j, out)
        out_S, out_P = out[keep_j], out[prune_j]
        comp = jnp.einsum("ps,pd->sd", B, out_P)
        bias = c @ out_P
    else:
        mu, sigma = jax.vmap(solve_mod.mlp_cov)(stats)
        B, c, diag = jax.vmap(solve_one)((mu, sigma), keep_j, prune_j, out)
        out_S = jnp.take_along_axis(out, keep_j[..., None], axis=1)
        out_P = jnp.take_along_axis(out, prune_j[..., None], axis=1)
        comp = jnp.einsum("rps,rpd->rsd", B, out_P)
        bias = jnp.einsum("rp,rpd->rd", c, out_P)
    if pc.compensate:
        new["out_proj"] = (out_S.astype(jnp.float32) + comp).astype(out.dtype)
        new["out_b"] = bias
    else:
        new["out_proj"] = out_S

    # gather every channel-wise parameter of the pruned inner dims
    in_proj = p["in_proj"]                 # (..., D, 2di)
    both = jnp.concatenate([keep_j, keep_j + di], axis=-1)
    new["in_proj"] = _gather(in_proj, both, axis=in_proj.ndim - 1)
    new["conv_w"] = _gather(p["conv_w"], keep_j, axis=p["conv_w"].ndim - 1)
    new["conv_b"] = _gather(p["conv_b"], keep_j, axis=p["conv_b"].ndim - 1)
    new["x_proj"] = _gather(p["x_proj"], keep_j, axis=p["x_proj"].ndim - 2)
    new["dt_proj"] = _gather(p["dt_proj"], keep_j, axis=p["dt_proj"].ndim - 1)
    new["dt_bias"] = _gather(p["dt_bias"], keep_j, axis=p["dt_bias"].ndim - 1)
    new["a_log"] = _gather(p["a_log"], keep_j, axis=p["a_log"].ndim - 2)
    new["d_skip"] = _gather(p["d_skip"], keep_j, axis=p["d_skip"].ndim - 1)
    report[unit.name] = jax.device_get(diag)
    return new


def _fold_attn_block(p, p2stats, unit: Unit, cfg, pc: PruneConfig, keep,
                     prune, report):
    """Attention QK fold. keep/prune: dims (class 1) or pairs (class 2/3),
    shape (..., G, n)."""
    new = dict(p)
    cls = unit.attn_class
    keep_j = jnp.asarray(keep)
    prune_j = jnp.asarray(prune)
    mla = unit.kind == "mla"
    qk, kk = ("w_uq_nope", "w_uk_nope") if mla else ("wq", "wk")
    wq, wk = p[qk], p[kk]                 # (..., D, H, dq)
    G = unit.n_groups
    qpg = unit.q_per_group
    dq_full = wq.shape[-1]

    # --- solve per (layer, group), vmapped over flattened leading dims
    lead = keep_j.shape[:-2]

    Gm = jnp.asarray(p2stats["G"])
    hv = jnp.asarray(p2stats["h"])
    t2 = jnp.asarray(p2stats["t2"])

    # flatten (lead..., G) into one vmap dim
    def fl(a, extra):
        return a.reshape((-1,) + a.shape[a.ndim - extra:])
    Gf = fl(Gm, Gm.ndim - len(lead) - 1)
    hf = fl(hv, hv.ndim - len(lead) - 1)
    t2f = t2.reshape(-1)

    if cls == 1:
        def s1(Gm, h, t2):
            lam = pc.lam * jnp.mean(jnp.real(jnp.diag(Gm)))
            sol = solve_mod.solve_full_m(Gm, h, t2, lam)
            if not pc.compensate:
                sol = dict(sol, M=jnp.zeros_like(sol["M"]))
            fq, fk = solve_mod.fold_full_m(sol["M"])
            return fq, fk, {"j_star": sol["j_star"],
                            "j_uncomp": sol["j_uncomp"], "rho2": sol["rho2"]}
        fq, fk, diag = jax.vmap(s1)(Gf, hf, t2f)
        dim_keep, dim_prune = keep_j, prune_j
    else:
        def s2(Gm, h, t2):
            lam = pc.lam * jnp.mean(jnp.real(jnp.diag(Gm)))
            if cls == 2:
                sol = solve_mod.solve_diag_complex(Gm, h, t2, lam)
            else:
                sol = solve_mod.solve_diag_real(Gm, h, t2, lam)
            m = sol["m"] if pc.compensate else jnp.zeros_like(sol["m"])
            if cls == 2:
                bq, bk = solve_mod.fold_diag_complex(m)
            else:
                bq, bk = solve_mod.fold_diag_real(m)
            return bq, bk, {"j_star": sol["j_star"],
                            "j_uncomp": sol["j_uncomp"], "rho2": sol["rho2"]}
        fq, fk, diag = jax.vmap(s2)(Gf, hf, t2f)
        dim_keep = solve_mod.pairs_to_dims(keep_j)
        dim_prune = solve_mod.pairs_to_dims(prune_j)

    def unfl(a):
        return a.reshape(lead + (G,) + a.shape[1:])
    fq, fk = unfl(fq), unfl(fk)
    diag = jax.tree.map(unfl, diag)

    # --- gather kept dims + apply folds
    # wq: (..., D, H, dq) -> (..., D, G, qpg, dq)
    wq_g = wq.reshape(wq.shape[:-2] + (G, qpg, dq_full))
    wk_g = wk.reshape(wk.shape[:-2] + (G, 1, dq_full))
    idx_q = dim_keep[..., None, :, None, :]    # (...,1,G,1,n)
    idx_q = jnp.broadcast_to(
        idx_q, wq_g.shape[:-1] + (dim_keep.shape[-1],))
    wq_S = jnp.take_along_axis(wq_g, idx_q, axis=-1)
    idx_k = jnp.broadcast_to(dim_keep[..., None, :, None, :],
                             wk_g.shape[:-1] + (dim_keep.shape[-1],))
    wk_S = jnp.take_along_axis(wk_g, idx_k, axis=-1)

    if cls == 1:
        wq_new = jnp.einsum("...dgqs,...gst->...dgqt",
                            wq_S.astype(jnp.float32), fq)
        wk_new = jnp.einsum("...dgqs,...gst->...dgqt",
                            wk_S.astype(jnp.float32), fk)
    elif cls == 2:
        # per-pair 2x2 blocks: (..., G, p, 2, 2)
        shp_q = wq_S.shape[:-1] + (dim_keep.shape[-1] // 2, 2)
        wq_pairs = wq_S.reshape(shp_q).astype(jnp.float32)
        wq_new = jnp.einsum("...dgqpi,...gpij->...dgqpj", wq_pairs, fq)
        wq_new = wq_new.reshape(wq_S.shape)
        shp_k = wk_S.shape[:-1] + (dim_keep.shape[-1] // 2, 2)
        wk_pairs = wk_S.reshape(shp_k).astype(jnp.float32)
        wk_new = jnp.einsum("...dgqpi,...gpij->...dgqpj", wk_pairs, fk)
        wk_new = wk_new.reshape(wk_S.shape)
    else:
        # class 3: fold into qk-norm scales (per-head vectors)
        wq_new = wq_S.astype(jnp.float32)
        wk_new = wk_S.astype(jnp.float32)

    new[qk] = wq_new.reshape(wq.shape[:-2] + (G * qpg,
                                              dim_keep.shape[-1])) \
        .astype(wq.dtype)
    new[kk] = wk_new.reshape(wk.shape[:-2] + (G, dim_keep.shape[-1])) \
        .astype(wk.dtype)

    # biases (pre-rope additive -> transformed by the same fold)
    if "bq" in p and not mla:
        bq = p["bq"]                       # (..., H, dq)
        bq_g = bq.reshape(bq.shape[:-2] + (G, qpg, dq_full))
        idx = jnp.broadcast_to(dim_keep[..., :, None, :],
                               bq_g.shape[:-1] + (dim_keep.shape[-1],))
        bq_S = jnp.take_along_axis(bq_g, idx, axis=-1).astype(jnp.float32)
        bk = p["bk"]
        bk_g = bk.reshape(bk.shape[:-2] + (G, 1, dq_full))
        idxk = jnp.broadcast_to(dim_keep[..., :, None, :],
                                bk_g.shape[:-1] + (dim_keep.shape[-1],))
        bk_S = jnp.take_along_axis(bk_g, idxk, axis=-1).astype(jnp.float32)
        if cls == 1:
            bq_S = jnp.einsum("...gqs,...gst->...gqt", bq_S, fq)
            bk_S = jnp.einsum("...gqs,...gst->...gqt", bk_S, fk)
        elif cls == 2:
            sq = bq_S.shape[:-1] + (dim_keep.shape[-1] // 2, 2)
            bq_S = jnp.einsum("...gqpi,...gpij->...gqpj",
                              bq_S.reshape(sq), fq).reshape(bq_S.shape)
            sk = bk_S.shape[:-1] + (dim_keep.shape[-1] // 2, 2)
            bk_S = jnp.einsum("...gqpi,...gpij->...gqpj",
                              bk_S.reshape(sk), fk).reshape(bk_S.shape)
        new["bq"] = bq_S.reshape(bq.shape[:-2]
                                 + (G * qpg, dim_keep.shape[-1]))
        new["bk"] = bk_S.reshape(bk.shape[:-2] + (G, dim_keep.shape[-1]))

    # qk-norm scales: gather kept dims; class 3 folds the scale here
    if "q_scale" in p:
        qs = p["q_scale"]                  # (..., dq) shared across heads
        ks_ = p["k_scale"]
        def expand_scale(s, n_rep):
            # (..., dq) -> (..., n_rep, dq)
            return jnp.broadcast_to(s[..., None, :],
                                    s.shape[:-1] + (n_rep, s.shape[-1]))
        qs_h = expand_scale(qs, G * qpg)
        ks_h = expand_scale(ks_, G)
        qs_g = qs_h.reshape(qs_h.shape[:-2] + (G, qpg, dq_full))
        idx = jnp.broadcast_to(dim_keep[..., :, None, :],
                               qs_g.shape[:-1] + (dim_keep.shape[-1],))
        qs_S = jnp.take_along_axis(qs_g, idx, axis=-1)
        ks_g = ks_h.reshape(ks_h.shape[:-2] + (G, 1, dq_full))
        idxk = jnp.broadcast_to(dim_keep[..., :, None, :],
                                ks_g.shape[:-1] + (dim_keep.shape[-1],))
        ks_S = jnp.take_along_axis(ks_g, idxk, axis=-1)
        if cls == 3:
            # per-pair scale expanded to both dims of the pair
            def pair_expand(v):
                return jnp.repeat(v, 2, axis=-1)
            qs_S = qs_S * pair_expand(fq)[..., :, None, :]
            ks_S = ks_S * pair_expand(fk)[..., :, None, :]
        new["q_scale"] = qs_S.reshape(qs_h.shape[:-2]
                                      + (G * qpg, dim_keep.shape[-1]))
        new["k_scale"] = ks_S.reshape(ks_h.shape[:-2]
                                      + (G, dim_keep.shape[-1]))

    # rope frequency buffers: gather kept pair frequencies per head
    if "rope_inv_q" in p:
        ri_q = p["rope_inv_q"]             # (..., H, dq/2)
        ri_k = p["rope_inv_k"]             # (..., G, dq/2)
        pk = keep_j                        # pair indices (..., G, p)
        riq_g = ri_q.reshape(ri_q.shape[:-2] + (G, qpg, dq_full // 2))
        idx = jnp.broadcast_to(pk[..., :, None, :],
                               riq_g.shape[:-1] + (pk.shape[-1],))
        riq = jnp.take_along_axis(riq_g, idx, axis=-1)
        new["rope_inv_q"] = riq.reshape(ri_q.shape[:-2]
                                        + (G * qpg, pk.shape[-1]))
        idxk = jnp.broadcast_to(pk, ri_k.shape[:-1] + (pk.shape[-1],))
        new["rope_inv_k"] = jnp.take_along_axis(ri_k, idxk, axis=-1)

    report[unit.name] = jax.device_get(diag)
    return new


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def corp_prune(model, params, calib_batches: Callable[[], Iterable],
               pc: PruneConfig = PruneConfig(),
               progress: Optional[Callable[[str], None]] = None,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 8,
               mesh=None, stats_dtype="float32",
               one_traversal: bool = False, spec_margin: float = 0.25):
    """One-shot CORP (Alg. 1): calibrate -> rank -> compensate -> fold.

    Args:
      model: model exposing ``apply(params, batch, taps=...)`` and ``cfg``.
      params: dense parameter pytree (any dtype; statistics are fp32).
      calib_batches: zero-arg callable returning a fresh iterator of
        batches (traversed twice classically: rank pass + attention
        compensation pass; once with ``one_traversal=True`` on the
        speculative hit path).
      pc: sparsities/ridge/ranking-policy knobs, see ``PruneConfig``.
      progress: optional ``fn(str)`` called at each pipeline stage.
      ckpt_dir: when set, each calibration pass checkpoints its statistics
        accumulator every ``ckpt_every`` batches under ``<ckpt_dir>/passN``
        (``pass12`` for the fused one-traversal pass) and resumes from the
        newest valid one (restartable long passes).
      mesh: optional ``jax.sharding.Mesh`` — all calibration passes then
        run mesh-sharded (``CalibrationEngine(mesh=...)``): per-unit
        covariance/Gram blocks column-sharded over the model axis, batch
        contributions psum-reduced, no replicated full Sigma on any device.
        Ranking and folding still happen on host from the gathered sums.
      stats_dtype: activation streaming dtype for all calibration passes
        ("float32" default; "bfloat16" halves calibration HBM traffic,
        accumulators stay fp32 — see ``CalibrationEngine``).
      one_traversal: fuse both passes into a single traversal of the
        calibration set: pass 1 speculatively accumulates pass-2
        cross-moments against top-k candidate keep-sets (sized
        ``keep_n * (1 + spec_margin)`` from the first batch's running
        scores). Attention units whose final keep-set lands inside the
        candidates — the common case, see docs/pipeline.md's hit-rate
        study — solve compensation with zero additional traversals; the
        rest fall back to one targeted mini pass 2.
      spec_margin: candidate safety margin for ``one_traversal`` (0.25
        default — ``keep_n * margin`` extra candidate slots per group;
        memory grows as ``(1+margin)^4`` for class-1 units).

    Returns:
      ``(pruned_params, pruned_config, report)`` — a physically smaller
      standard model (reduced d_ff / per-head qk dims) built by the same
      model code, its config, and per-unit distortion diagnostics + stage
      timings. ``report["traversals"]`` counts calibration-set traversals;
      with ``one_traversal=True``, ``report["speculative"]`` records the
      margin, candidate sizes, and hit/miss units.
    """
    import copy
    import time
    cfg = model.cfg
    units = discover_units(cfg)
    say = progress or (lambda s: None)
    report = {"timing": {}, "units": {}}

    calls = [0]
    _orig_batches = calib_batches

    def calib_batches():            # noqa: F811 — counts traversals
        calls[0] += 1
        return _orig_batches()

    speculate = (one_traversal and pc.attn_sparsity > 0
                 and any(u.kind in _ATTN_KINDS for u in units))
    spec_plan = spec_stats = None
    t0 = time.time()
    if speculate:
        say("pass 1+2: one-traversal speculative statistics")
        p1, spec_plan, spec_stats = _speculative_pass(
            model, units, params, calib_batches(), pc,
            spec_margin=spec_margin, mesh=mesh, stats_dtype=stats_dtype,
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every)
    else:
        say("pass 1: ranking/MLP statistics")
        engine1 = calib_mod.CalibrationEngine(model, units, phase=1,
                                              mesh=mesh,
                                              stats_dtype=stats_dtype)
        p1 = engine1.run(params, calib_batches(),
                         checkpointer=_checkpointer(ckpt_dir, "pass1",
                                                    ckpt_every))
    report["timing"]["pass1"] = time.time() - t0

    # --- ranking ----------------------------------------------------------
    t0 = time.time()
    plan = {}       # unit.name -> (keep, prune) np arrays
    for u in units:
        st = p1[u.name]
        if u.kind in ("mlp", "rwkv_mlp", "moe", "mamba"):
            if u.kind == "mamba" and not pc.include_mamba:
                continue
            if pc.mlp_sparsity <= 0:
                continue
            blockp = get_block(params, u)
            if u.shared_expert:
                blockp = blockp["shared"]
            w2 = blockp["wv" if u.kind == "rwkv_mlp"
                        else "out_proj" if u.kind == "mamba" else "wd"]
            keep_n = _keep_count(u.d_hidden if u.kind != "mamba"
                                 else cfg.mamba.expand * cfg.d_model,
                                 pc.mlp_sparsity, pc.round_to)
            keep, prune = rank_mod.rank_mlp(st, np.asarray(w2), keep_n,
                                            pc.rank_policy)
            plan[u.name] = (keep, prune)
        elif u.kind in _ATTN_KINDS:
            if pc.attn_sparsity <= 0:
                continue
            full = st["rank"].shape[-1]       # dims (cls1) or pairs (cls2/3)
            keep, prune = rank_mod.rank_attn(st, _attn_keep_n(u, full, pc))
            plan[u.name] = (keep, prune)
    e_plan = _moe_expert_plan(units, p1, cfg, pc)
    report["timing"]["rank"] = time.time() - t0

    # --- pass 2: attention compensation statistics -------------------------
    attn_plan = {u.name: plan[u.name] for u in units
                 if u.kind in _ATTN_KINDS and u.name in plan}
    p2 = {}
    if attn_plan:
        t0 = time.time()
        p2, misses = _resolve_attn_pass2(
            model, units, params, calib_batches, attn_plan, spec_plan,
            spec_stats, mesh=mesh, stats_dtype=stats_dtype,
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, say=say)
        if speculate:
            report["speculative"] = {
                "margin": spec_margin,
                "candidates": {k: int(v.shape[-1])
                               for k, v in spec_plan.items()},
                "hits": sorted(set(attn_plan) - set(misses)),
                "misses": sorted(misses)}
        report["timing"]["pass2"] = time.time() - t0

    # --- fold -------------------------------------------------------------
    t0 = time.time()
    say("closed-form compensation + fold")
    new_params = copy.deepcopy(jax.device_get(params))
    for u in units:
        if u.name not in plan and u.name not in e_plan:
            continue
        block = get_block(new_params, u)
        if u.name in plan:
            keep, prune = plan[u.name]
            if u.kind in ("mlp", "rwkv_mlp"):
                tgt = block["shared"] if u.shared_expert else block
                folded = _fold_mlp_block(tgt, p1[u.name], u, pc, keep,
                                         prune, report["units"])
                if u.shared_expert:
                    block = dict(block, shared=folded)
                else:
                    block = folded
            elif u.kind == "moe":
                block = _fold_moe_block(block, p1[u.name], u, pc, keep,
                                        prune, report["units"])
            elif u.kind == "mamba":
                block = _fold_mamba_block(block, p1[u.name], u, pc, keep,
                                          prune, report["units"])
            else:
                block = _fold_attn_block(block, p2[u.name], u, cfg, pc,
                                         keep, prune, report["units"])
        if u.name in e_plan:
            ek, ep = e_plan[u.name]
            block = _fold_moe_experts(block, p1[u.name], u, pc, ek, ep,
                                      report["units"])
        set_block(new_params, u, block)
    report["timing"]["fold"] = time.time() - t0
    report["plan_sizes"] = {k: v[0].shape for k, v in plan.items()}
    report["plan_sizes"].update(
        {k + "/experts": v[0].shape for k, v in e_plan.items()})
    report["traversals"] = calls[0]

    new_cfg = cfg.pruned(pc.mlp_sparsity if pc.mlp_sparsity > 0 else 0.0,
                         pc.attn_sparsity if pc.attn_sparsity > 0 else 0.0,
                         round_to=pc.round_to,
                         expert_sparsity=pc.expert_sparsity)
    if not pc.include_mamba and new_cfg.d_inner_kept is not None:
        new_cfg = new_cfg.replace(d_inner_kept=None)
    return new_params, new_cfg, report


def corp_prune_streamed(model, params, calib_batches: Callable[[], Iterable],
                        pc: PruneConfig = PruneConfig(), *,
                        unit_group_size: int = 2,
                        progress: Optional[Callable[[str], None]] = None,
                        mesh=None, stats_dtype="float32",
                        one_traversal: bool = False,
                        spec_margin: float = 0.25):
    """Memory-bounded CORP: identical output to ``corp_prune`` (statistics
    are linear, so partitioning the unit set changes nothing), but only
    ``unit_group_size`` units' statistics are resident at a time.

    At 671B scale the covariance blocks dominate (e.g. one dense-FFN
    Sigma is d_ff^2 fp32 = 1.3 GB at 18432; a full MoE layer's per-expert
    stack is E x d_expert^2 = 4.3 GB) — streaming re-traverses the
    calibration set per group and bounds resident statistics to one group,
    which is how a pruning pass over thousands of layers stays inside host
    memory and can checkpoint between groups (DESIGN.md §2.3).

    Args:
      unit_group_size: units whose statistics are resident concurrently.
      mesh: optional ``jax.sharding.Mesh`` — composes both bounds: the
        active group's statistics are the only ones resident AND they are
        model-sharded across the mesh (``CalibrationEngine(mesh=...)``),
        so per-device residency is group_size x Sigma/m. This is the
        671B-scale configuration from ROADMAP's "Sharded engine" item.
      stats_dtype: activation streaming dtype for every group's passes
        ("float32" default; "bfloat16" halves calibration HBM traffic —
        composes with both bounds above, since it shrinks the *stream*
        while they bound the *resident statistics*).
      one_traversal: speculative pass fusion per unit group — a group with
        attention units traverses the calibration set once instead of
        twice on the speculative hit path (see ``corp_prune``); the
        candidate accumulators obey the same residency bound (they exist
        only for the active group).
      spec_margin: candidate safety margin, as in ``corp_prune``.

    Returns:
      ``(pruned_params, pruned_config, report)`` as ``corp_prune``, with
      ``report['groups']`` counting processed unit groups and
      ``report['traversals']`` total calibration-set traversals.
    """
    import copy
    cfg = model.cfg
    all_units = discover_units(cfg)
    say = progress or (lambda s: None)
    new_params = copy.deepcopy(jax.device_get(params))
    report = {"timing": {}, "units": {}, "groups": 0}
    merged_plan = {}
    spec_report = {"margin": spec_margin, "candidates": {}, "hits": [],
                   "misses": []}

    calls = [0]
    _orig_batches = calib_batches

    def calib_batches():            # noqa: F811 — counts traversals
        calls[0] += 1
        return _orig_batches()

    groups = [all_units[i:i + unit_group_size]
              for i in range(0, len(all_units), unit_group_size)]
    for gi, units in enumerate(groups):
        say(f"group {gi+1}/{len(groups)}: "
            + ", ".join(u.name for u in units))
        speculate = (one_traversal and pc.attn_sparsity > 0
                     and any(u.kind in _ATTN_KINDS for u in units))
        spec_plan = spec_stats = None
        if speculate:
            p1, spec_plan, spec_stats = _speculative_pass(
                model, units, params, calib_batches(), pc,
                spec_margin=spec_margin, mesh=mesh, stats_dtype=stats_dtype)
        else:
            p1 = calib_mod.CalibrationEngine(model, units, phase=1,
                                             mesh=mesh,
                                             stats_dtype=stats_dtype) \
                .run(params, calib_batches())
        plan = {}
        for u in units:
            st = p1[u.name]
            if u.kind in ("mlp", "rwkv_mlp", "moe", "mamba"):
                if (u.kind == "mamba" and not pc.include_mamba) \
                        or pc.mlp_sparsity <= 0:
                    continue
                blockp = get_block(params, u)
                if u.shared_expert:
                    blockp = blockp["shared"]
                w2 = blockp["wv" if u.kind == "rwkv_mlp"
                            else "out_proj" if u.kind == "mamba" else "wd"]
                keep_n = _keep_count(u.d_hidden if u.kind != "mamba"
                                     else cfg.mamba.expand * cfg.d_model,
                                     pc.mlp_sparsity, pc.round_to)
                plan[u.name] = rank_mod.rank_mlp(st, np.asarray(w2), keep_n,
                                                 pc.rank_policy)
            elif u.kind in _ATTN_KINDS and pc.attn_sparsity > 0:
                full = st["rank"].shape[-1]
                plan[u.name] = rank_mod.rank_attn(
                    st, _attn_keep_n(u, full, pc))
        e_plan = _moe_expert_plan(units, p1, cfg, pc)
        attn_plan = {u.name: plan[u.name] for u in units
                     if u.kind in _ATTN_KINDS and u.name in plan}
        p2 = {}
        if attn_plan:
            p2, misses = _resolve_attn_pass2(
                model, units, params, calib_batches, attn_plan, spec_plan,
                spec_stats, mesh=mesh, stats_dtype=stats_dtype, say=say)
            if speculate:
                spec_report["candidates"].update(
                    {k: int(v.shape[-1]) for k, v in spec_plan.items()})
                spec_report["hits"] += sorted(set(attn_plan) - set(misses))
                spec_report["misses"] += sorted(misses)
        for u in units:
            if u.name not in plan and u.name not in e_plan:
                continue
            block = get_block(new_params, u)
            if u.name in plan:
                keep, prune = plan[u.name]
                if u.kind in ("mlp", "rwkv_mlp"):
                    tgt = block["shared"] if u.shared_expert else block
                    folded = _fold_mlp_block(tgt, p1[u.name], u, pc, keep,
                                             prune, report["units"])
                    block = dict(block, shared=folded) if u.shared_expert \
                        else folded
                elif u.kind == "moe":
                    block = _fold_moe_block(block, p1[u.name], u, pc, keep,
                                            prune, report["units"])
                elif u.kind == "mamba":
                    block = _fold_mamba_block(block, p1[u.name], u, pc,
                                              keep, prune, report["units"])
                else:
                    block = _fold_attn_block(block, p2[u.name], u, cfg, pc,
                                             keep, prune, report["units"])
            if u.name in e_plan:
                ek, ep = e_plan[u.name]
                block = _fold_moe_experts(block, p1[u.name], u, pc, ek, ep,
                                          report["units"])
            set_block(new_params, u, block)
        merged_plan.update(plan)
        merged_plan.update({k + "/experts": v for k, v in e_plan.items()})
        report["groups"] += 1

    new_cfg = cfg.pruned(pc.mlp_sparsity if pc.mlp_sparsity > 0 else 0.0,
                         pc.attn_sparsity if pc.attn_sparsity > 0 else 0.0,
                         round_to=pc.round_to,
                         expert_sparsity=pc.expert_sparsity)
    if not pc.include_mamba and new_cfg.d_inner_kept is not None:
        new_cfg = new_cfg.replace(d_inner_kept=None)
    report["plan_sizes"] = {k: v[0].shape for k, v in merged_plan.items()}
    report["traversals"] = calls[0]
    if one_traversal and spec_report["candidates"]:
        report["speculative"] = spec_report
    return new_params, new_cfg, report
