"""deepseek-7b [dense] — llama-arch (MHA: kv_heads == heads). [arXiv:2401.02954; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="lm",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    vocab_size=102400,
    act="silu",
    mlp_kind="glu",
    rope_theta=1e4,
)
