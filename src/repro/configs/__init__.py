"""Config registry: ``get_config(arch_id)`` + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (MLAConfig, MambaConfig, ModelConfig,
                                MoEConfig, RWKVConfig, ShapeConfig, SHAPES)

_MODULES = {
    "granite-8b": "granite_8b",
    "gemma3-1b": "gemma3_1b",
    "deepseek-7b": "deepseek_7b",
    "qwen2-1.5b": "qwen2_1_5b",
    "internvl2-26b": "internvl2_26b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "rwkv6-3b": "rwkv6_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}

ARCH_IDS = tuple(_MODULES)

DEIT_IDS = ("deit-tiny", "deit-small", "deit-base", "deit-large", "deit-huge")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id in _MODULES:
        import importlib
        mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
        return mod.CONFIG
    if arch_id in DEIT_IDS:
        from repro.configs import deit
        return getattr(deit, arch_id.upper().replace("-", "_"))
    raise KeyError(f"unknown arch id {arch_id!r}; known: {ARCH_IDS + DEIT_IDS}")


def reduced(cfg: ModelConfig, *, d_model: int = 64, layers_scale: int = 1) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests.

    Keeps the structural pattern (GQA ratio, MoE, MLA, hybrid interleave,
    enc-dec, frontends) but shrinks width/depth/experts/vocab.
    """
    period = len(cfg.pattern)
    if cfg.moe is not None:
        import math
        period = math.lcm(period, cfg.moe_every)
    n_layers = max(period, 2) * layers_scale
    if cfg.first_k_dense:
        n_layers += 1
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, n_heads * cfg.n_kv_heads // cfg.n_heads)
    n_heads = n_kv * max(1, n_heads // n_kv)
    d_head = 16
    kw = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=d_head,
        d_ff=4 * d_model if cfg.moe is None else 2 * d_model,
        vocab_size=min(cfg.vocab_size, 503) if cfg.vocab_size else 0,
        sliding_window=8,
        dtype="float32",
        vocab_round=8,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_expert=2 * d_model,
            num_shared=min(cfg.moe.num_shared, 1))
        kw["d_ff"] = 2 * d_model
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                              qk_rope_dim=8, v_dim=16)
    if cfg.mamba is not None:
        kw["mamba"] = MambaConfig(d_state=4, d_conv=4, expand=2)
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_dim=16, decay_lora=8)
        kw["n_heads"] = d_model // 16
        kw["n_kv_heads"] = d_model // 16
    if cfg.first_k_dense:
        kw["first_k_dense"] = 1
        kw["dense_d_ff"] = 4 * d_model
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = 2
    if cfg.family == "vit":
        kw["img_size"] = 32
        kw["patch"] = 8
        kw["n_classes"] = min(cfg.n_classes, 10) or 10
    return cfg.replace(name=cfg.name + "-reduced", **kw)


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "MambaConfig", "RWKVConfig",
    "ShapeConfig", "SHAPES", "ARCH_IDS", "DEIT_IDS", "get_config", "reduced",
]
