"""DeiT family — the paper's own architectures (Touvron et al. 2021).

Plain ViT: LayerNorm, GELU two-matrix MLP, learned positional embeddings,
cls token, classification head. Used for the faithful CORP reproduction,
benchmarks and examples.
"""
from repro.configs.base import ModelConfig


def _deit(name, n_layers, d_model, n_heads, d_ff, patch=16, img=224,
          n_classes=1000):
    return ModelConfig(
        name=name,
        family="vit",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_heads,
        d_head=d_model // n_heads,
        d_ff=d_ff,
        vocab_size=0,
        act="gelu",
        mlp_kind="plain",
        qkv_bias=True,
        norm_kind="layernorm",
        frontend="patch_conv",
        n_classes=n_classes,
        img_size=img,
        patch=patch,
        dtype="float32",
    )


DEIT_TINY = _deit("deit-tiny", 12, 192, 3, 768)
DEIT_SMALL = _deit("deit-small", 12, 384, 6, 1536)
DEIT_BASE = _deit("deit-base", 12, 768, 12, 3072)
DEIT_LARGE = _deit("deit-large", 24, 1024, 16, 4096)
DEIT_HUGE = _deit("deit-huge", 32, 1280, 16, 5120, patch=14)

CONFIG = DEIT_BASE
