"""Model configuration dataclasses.

A single ``ModelConfig`` describes every architecture in the assigned pool
(dense GQA LMs, MLA, MoE, RWKV6, Mamba hybrids, encoder-decoder, ViT/VLM
backbones) plus the paper's own DeiT family.  Pruned models are expressed by
the same dataclass with ``d_ff_kept`` / ``qk_kept`` / ``d_inner_kept`` set —
the model code reads effective dimensions through the ``eff_*`` properties so
dense and pruned models share one implementation.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert hidden dim
    num_shared: int = 0            # shared (always-on) experts
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_dim: int


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64           # rank of data-dependent decay LoRA


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # 'lm' | 'encdec' | 'vit'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # block composition ------------------------------------------------
    # mixer pattern, cycled over depth. entries: 'attn' | 'swa' | 'mamba' | 'rwkv'
    pattern: Tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    moe_every: int = 1             # layer i is MoE iff moe and (i % moe_every == moe_every-1)
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    act: str = "silu"              # 'silu' | 'gelu' | 'relu2'
    mlp_kind: str = "glu"          # 'glu' (gated) | 'plain' (two-matrix, ViT/DeiT)
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 1024
    rope_theta: float = 1e4
    rope_theta_local: float = 1e4  # theta for 'swa' layers (gemma3 uses 1e4 local / 1e6 global)
    first_k_dense: int = 0         # first k layers use dense FFN even in MoE models
    dense_d_ff: Optional[int] = None  # FFN dim for those dense layers (deepseek-v3: 18432)
    dense_d_ff_kept: Optional[int] = None  # pruned dim for those dense layers
    norm_kind: str = "rmsnorm"     # 'rmsnorm' | 'layernorm'
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # encoder-decoder ----------------------------------------------------
    n_enc_layers: int = 0          # >0 => family 'encdec'
    cross_attend: bool = False
    # vit / stub frontends -----------------------------------------------
    frontend: Optional[str] = None  # 'patch_stub' | 'frame_stub' | 'patch_conv'
    n_classes: int = 0
    img_size: int = 224
    patch: int = 16
    pool: str = "cls"              # 'cls' | 'mean'
    # pruning state (CORP) -------------------------------------------------
    d_ff_kept: Optional[int] = None     # kept MLP hidden channels (per expert for MoE)
    qk_kept: Optional[int] = None       # kept per-head qk dims (nope dims for MLA)
    d_inner_kept: Optional[int] = None  # kept mamba inner channels (beyond-paper)
    experts_kept: Optional[int] = None  # kept routed experts (beyond-paper)
    # numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    vocab_round: int = 128         # embedding table padded to a multiple of this

    # -- derived ------------------------------------------------------------
    @property
    def eff_d_ff(self) -> int:
        return self.d_ff if self.d_ff_kept is None else self.d_ff_kept

    @property
    def eff_d_expert(self) -> int:
        assert self.moe is not None
        return self.moe.d_expert if self.d_ff_kept is None else self.d_ff_kept

    @property
    def eff_num_experts(self) -> int:
        assert self.moe is not None
        return self.moe.num_experts if self.experts_kept is None \
            else self.experts_kept

    @property
    def eff_dense_d_ff(self) -> Optional[int]:
        if self.dense_d_ff is None:
            return None
        return self.dense_d_ff_kept or self.dense_d_ff

    @property
    def qk_full(self) -> int:
        """Full (unpruned) per-head qk dim; prunable part only for MLA (nope)."""
        if self.mla is not None:
            return self.mla.qk_nope_dim
        return self.d_head

    @property
    def eff_qk(self) -> int:
        return self.qk_full if self.qk_kept is None else self.qk_kept

    @property
    def eff_d_inner(self) -> int:
        assert self.mamba is not None
        full = self.mamba.expand * self.d_model
        return full if self.d_inner_kept is None else self.d_inner_kept

    @property
    def padded_vocab(self) -> int:
        r = self.vocab_round
        return ((self.vocab_size + r - 1) // r) * r

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Mixer kind for every layer (pattern cycled over depth)."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def layer_is_moe(self, i: int) -> bool:
        if self.moe is None or i < self.first_k_dense:
            return False
        return i % self.moe_every == self.moe_every - 1

    def layer_spec(self, i: int) -> Tuple[str, bool]:
        """(mixer kind, is_moe) for absolute layer index i."""
        return (self.layer_kinds[i], self.layer_is_moe(i))

    def layout(self):
        """Depth layout for scan-over-layers compilation.

        Returns a list of segments; each segment is ``("unroll", [abs_idx])``
        or ``("scan", n_reps, [abs_idx of first rep's layers])`` where every
        rep of a scanned segment has identical per-position layer specs.
        """
        import math
        L = self.n_layers
        segs = []
        start = 0
        if self.first_k_dense > 0:
            segs.append(("unroll", list(range(self.first_k_dense))))
            start = self.first_k_dense
        p = len(self.pattern)
        if self.moe is not None:
            p = math.lcm(p, self.moe_every)
        rem = L - start
        # period must reproduce identical (kind, moe) specs across reps
        def specs_ok(period: int) -> bool:
            base = [self.layer_spec(start + j) for j in range(period)]
            for r in range(1, rem // period):
                for j in range(period):
                    if self.layer_spec(start + r * period + j) != base[j]:
                        return False
            return True
        while p > 1 and not specs_ok(p):
            p += 1  # defensive; should not trigger for assigned archs
        n_full = rem // p
        if n_full > 0:
            segs.append(("scan", n_full, list(range(start, start + p))))
        tail_start = start + n_full * p
        if tail_start < L:
            segs.append(("unroll", list(range(tail_start, L))))
        return segs

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # CORP helpers -----------------------------------------------------------
    def pruned(self, mlp_sparsity: float = 0.0, attn_sparsity: float = 0.0,
               round_to: int = 1,
               expert_sparsity: float = 0.0) -> "ModelConfig":
        """Config after CORP pruning at the given sparsities.

        ``expert_sparsity`` removes whole routed experts (MoE configs
        only); the kept count never drops below ``top_k`` so routing stays
        well-defined.
        """
        def keep(full: int, s: float, rt: int = round_to) -> int:
            k = int(round(full * (1.0 - s)))
            if rt > 1:
                k = max(rt, (k // rt) * rt)
            return max(1, min(full, k))

        kw = {}
        if mlp_sparsity > 0:
            full_ff = self.moe.d_expert if self.moe is not None else self.d_ff
            kw["d_ff_kept"] = keep(full_ff, mlp_sparsity)
            if self.dense_d_ff:
                kw["dense_d_ff_kept"] = keep(self.dense_d_ff, mlp_sparsity)
            if self.mamba is not None:
                kw["d_inner_kept"] = keep(self.mamba.expand * self.d_model,
                                          mlp_sparsity)
        if attn_sparsity > 0 and self.has_attention:
            # rope archs prune whole rotary pairs (see repro.core.solve)
            pairwise = self.family == "lm" and self.rwkv is None \
                and self.mla is None
            if pairwise:
                kept_pairs = keep(self.qk_full // 2, attn_sparsity,
                                  max(1, round_to // 2))
                kw["qk_kept"] = 2 * kept_pairs
            else:
                kw["qk_kept"] = keep(self.qk_full, attn_sparsity)
        if expert_sparsity > 0 and self.moe is not None:
            kw["experts_kept"] = max(self.moe.top_k,
                                     keep(self.moe.num_experts,
                                          expert_sparsity, 1))
        return self.replace(**kw) if kw else self

    @property
    def has_attention(self) -> bool:
        return any(k in ("attn", "swa") for k in self.layer_kinds) or self.n_enc_layers > 0

    @property
    def is_hybrid(self) -> bool:
        kinds = set(self.layer_kinds)
        return len(kinds - {"attn", "swa"}) > 0 and len(kinds & {"attn", "swa"}) > 0

    @property
    def is_subquadratic(self) -> bool:
        """True if long-context decode is feasible (no full-attention on every layer)."""
        kinds = self.layer_kinds
        full = sum(1 for k in kinds if k == "attn")
        return full < len(kinds)  # any ssm/swa majority counts


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned input-shape cell."""
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
