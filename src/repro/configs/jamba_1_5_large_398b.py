"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

Jamba block: 8 layers with attention at position 4 (0-indexed), MoE on every
other layer (e:2).
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="lm",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,            # per-expert hidden (assigned)
    vocab_size=65536,
    act="silu",
    mlp_kind="glu",
    pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba"),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576, num_shared=0,
                  capacity_factor=1.25),
    moe_every=2,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)
