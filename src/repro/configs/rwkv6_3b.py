"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]

CORP QK pruning is inapplicable (no QK bilinear logits) — see
DESIGN.md §Arch-applicability. MLP (channel-mix) pruning applies.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="lm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # 2560 / head_dim 64
    n_kv_heads=40,
    d_head=64,
    d_ff=8960,
    vocab_size=65536,
    act="relu2",           # channel-mix uses squared ReLU
    mlp_kind="plain",
    norm_kind="layernorm",
    pattern=("rwkv",),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
)
