"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal. [arXiv:2308.11596; hf]

Audio frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings consumed by the encoder. 24 encoder + 24 decoder layers share the
assigned backbone dims.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,           # decoder layers
    n_enc_layers=24,
    cross_attend=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab_size=256206,
    act="relu2",
    mlp_kind="plain",
    norm_kind="layernorm",
    rope_theta=1e4,
    frontend="frame_stub",
)
