"""internvl2-26b [vlm] — InternViT frontend (stub) + InternLM2-20B backbone.
[arXiv:2404.16821; hf]

The modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings which the model prepends to the token stream.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="lm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=92553,
    act="silu",
    mlp_kind="glu",
    rope_theta=1e6,
    frontend="patch_stub",
)
