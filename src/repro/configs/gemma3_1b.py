"""gemma3-1b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="lm",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab_size=262144,
    act="gelu",
    mlp_kind="glu",
    qk_norm=True,
    pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
    sliding_window=512,
    rope_theta=1e6,
    rope_theta_local=1e4,
    tie_embeddings=True,
)
