"""qwen2-1.5b [dense] — GQA with QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="lm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab_size=151936,
    act="silu",
    mlp_kind="glu",
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
)
