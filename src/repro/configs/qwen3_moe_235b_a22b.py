"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="lm",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,             # per-expert hidden (assigned)
    vocab_size=151936,
    act="silu",
    mlp_kind="glu",
    qk_norm=True,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536, num_shared=0,
                  capacity_factor=1.25),
    moe_every=1,
    rope_theta=1e6,
)
