"""deepseek-v3-671b [moe] — MLA attention, 1 shared + 256 routed top-8 experts.
[arXiv:2412.19437; hf]

MTP (multi-token prediction) head is not modeled (orthogonal to pruning).
The assigned d_ff=2048 is the per-expert hidden dim; the first 3 layers use a
dense FFN of 18432 per the released config.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="lm",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,            # v head dim
    d_ff=2048,             # per-expert hidden (assigned)
    vocab_size=129280,
    act="silu",
    mlp_kind="glu",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_dim=128,
    ),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1,
                  capacity_factor=1.25),
    moe_every=1,
    first_k_dense=3,
    dense_d_ff=18432,
    rope_theta=1e4,
)
