"""Preallocated per-slot KV cache for the continuous-batching engine.

The engine runs ONE shared jitted decode step over all ``n_slots`` slots; a
request occupies a slot for its lifetime, and admitting a new request only
overwrites that slot's rows — no reshape, no reallocation, no recompile.

Layout
------
The global cache is the model's own prefill-cache pytree with the batch axis
widened to ``n_slots``. The batch axis is NOT uniformly the leading axis:
scanned-segment leaves are stacked ``(reps, B, max_len, ...)`` and enc-dec
decoder stacks are ``(n_layers, B, ...)``, so the per-leaf batch axis is
*inferred structurally* — ``jax.eval_shape`` of the prefill at two batch
sizes, and the axis whose dim differs is the batch axis. Slot writes are then
``dynamic_update_index_in_dim`` along that axis per leaf (donated, so XLA
updates in place).

Per-slot validity lives in the cache itself: every layer cache carries a
``pos`` (B,) valid-length which the decode attention turns into its key mask
(``key_idx <= pos``) — exactly the masked-cache contract of
``kernels/flash_decode/decode_attention``. Free slots simply keep decoding
into discarded lanes; their ``pos`` may walk past ``max_len``, where the
scatter drops out-of-bounds writes (jax semantics), so stale slots are inert
until the next admit overwrites them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

RECURRENT_KINDS = frozenset({"mamba", "rwkv"})


def cache_contract(cfg) -> str:
    """Classify a config's slot-cache contract: what a slot holds, how it
    grows, and what admit/retire must do (docs/serving.md).

    - ``"kv"``        — per-token KV rows up to ``max_len``; bytes grow with
      the budget; freed slots are inert under the ``pos`` mask.
    - ``"recurrent"`` — fixed-size wkv6/SSM state (hybrids with any
      mamba/rwkv layer count too: one contaminated layer breaks the KV
      row-locality premise for the whole stack); bytes constant in
      ``max_len``; retire must *reset* the state (a lossy whole-history
      summary has no mask to hide behind).
    - ``"encdec"``    — decoder self-attn KV plus a fixed cross-attn memory
      keyed by the encoder frames, not the prompt tokens.
    """
    if cfg.family == "encdec":
        return "encdec"
    if set(cfg.layer_kinds) & RECURRENT_KINDS:
        return "recurrent"
    return "kv"


def _infer_batch_axes(tree1, tree2):
    """Per-leaf batch axis: the first dim that differs between the two
    ShapeDtypeStruct trees (evaluated at two different batch sizes)."""
    def axis_of(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch axis found in cache leaf {a.shape}")
    return jax.tree.map(axis_of, tree1, tree2)


def cache_bytes(tree) -> int:
    """Total bytes held by a cache pytree."""
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


class SlotCache:
    """n_slots-wide preallocated decode cache with per-slot writes.

    Built lazily from the *shape* of the model's prefill cache (no forward
    pass): ``template_fn(batch)`` must return the prefill-cache
    ShapeDtypeStruct tree at that batch size (time axis already padded to
    ``max_len``).

    With a ``sharding`` (``serve.sharding.ServeSharding``) every leaf is
    explicitly placed on the mesh: the inferred slot axis shards over the
    data axis, payload dims over the model axis (``slot_specs`` — the
    per-contract table in docs/serving.md). The donated per-slot scatter
    is unchanged; pinning ``out_shardings`` keeps each write shard-local,
    and the batch-1 ``local_specs`` (data-replicated, model-sharded) are
    what the engine pins its prefill outputs to, so admit is a *sharded*
    scatter: the local cache arrives already split over the model axis and
    ``dynamic_update_index_in_dim`` runs per shard with no resharding.
    """

    def __init__(self, template_fn, n_slots: int, *, sharding=None,
                 name: str = "slot-cache"):
        self.n_slots = n_slots
        sds1, sds2 = template_fn(1), template_fn(2)
        self.batch_axes = _infer_batch_axes(sds1, sds2)
        self._template = template_fn(n_slots)
        self.sharding = sharding
        self.specs = self.local_specs = None
        self._shardings = self._local_shardings = None
        if sharding is not None:
            from repro.distrib.sharding import shardings_of
            from repro.serve.sharding import slot_specs
            kw = dict(data_axis=sharding.data_axis,
                      model_axis=sharding.model_axis, name=name)
            self.specs = slot_specs(self._template, self.batch_axes,
                                    sharding.mesh, **kw)
            self.local_specs = slot_specs(sds1, self.batch_axes,
                                          sharding.mesh, **kw)
            self._shardings = shardings_of(self.specs, sharding.mesh)
            self._local_shardings = shardings_of(self.local_specs,
                                                 sharding.mesh)
        self.cache = self._zeros()
        # donate the global cache so XLA updates the slot rows in place
        # (the batch-1 local cache has different shapes, so it can't donate)
        self._write = jax.jit(self._write_impl, donate_argnums=(0,),
                              out_shardings=self._shardings)

    def _zeros(self):
        z = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         self._template)
        return z if self._shardings is None else \
            jax.device_put(z, self._shardings)

    def reset(self):
        """Drop all slot contents (e.g. after compile warmup)."""
        self.cache = self._zeros()

    def _write_impl(self, global_c, local_c, slot):
        def put(g, l, ax):
            row = jax.lax.index_in_dim(l, 0, ax, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(
                g, row.astype(g.dtype), slot, ax)
        return jax.tree.map(put, global_c, local_c, self.batch_axes)

    def write_slot(self, local_cache, slot: int):
        """Admit: copy a batch-1 prefill cache into slot ``slot``."""
        self.cache = self._write(self.cache, local_cache,
                                 jnp.int32(slot))

    @property
    def bytes(self) -> int:
        return cache_bytes(self.cache)

    @property
    def slot_bytes(self) -> int:
        """Bytes one slot occupies (the per-request cache cost)."""
        return self.bytes // self.n_slots

    @property
    def device_bytes(self) -> int:
        """Largest per-device resident bytes of the live cache — the number
        mesh sharding exists for (== ``bytes`` unsharded). Measured from
        the arrays' addressable shards, like the calibration footprint
        gate (benchmarks/bench_calib_sharded.py)."""
        total = 0
        for leaf in jax.tree.leaves(self.cache):
            shards = getattr(leaf, "addressable_shards", None)
            total += max(s.data.nbytes for s in shards) if shards \
                else leaf.nbytes
        return int(total)


class RecurrentSlotCache(SlotCache):
    """Slot cache for the *recurrent* contract: each slot holds a fixed-size
    wkv6/SSM state instead of growing KV rows.

    Admit is the same donated per-slot scatter as ``SlotCache`` (recurrent
    states have no time axis, so the whole lane is replaced), decode is the
    same shared step — the difference is retire. A freed KV slot is inert
    behind its ``pos`` mask, but a recurrent state is a lossy summary of the
    whole history with no mask to hide behind, so ``reset_slot`` scatters
    the empty-history (zero) state back into the lane. ``slot_bytes`` is
    constant in ``max_len`` — the cheaper cache contract the recurrent
    bench row gates (benchmarks/bench_serve.py).
    """

    def __init__(self, template_fn, n_slots: int, *, sharding=None,
                 name: str = "slot-cache"):
        super().__init__(template_fn, n_slots, sharding=sharding, name=name)
        # batch-1 empty-history state, reused by every reset_slot scatter
        # (placed like a prefill output, so the reset stays shard-local)
        blank = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             template_fn(1))
        self._blank = blank if self._local_shardings is None else \
            jax.device_put(blank, self._local_shardings)

    def reset_slot(self, slot: int):
        """Retire/cancel: return ``slot``'s lane to the empty-history
        state (the state the next admit's scatter expects to replace)."""
        self.write_slot(self._blank, slot)
