"""Async serving front-end over ``ServeEngine``: streaming, deadlines,
backpressure, prefix reuse.

``ServeEngine`` turns a static request list into completions; production
traffic instead *arrives* — over a wire, at its own rate, with callers that
hang up. This layer adds the request dynamics (docs/serving.md "Front-end"):

- **streaming** — every submitted request returns a handle whose token
  iterator yields each token as the shared decode step produces it, not
  after completion (``ServeFrontend.stream`` / ``AsyncServeFrontend``).
- **admission control + backpressure** — free slots admit immediately;
  otherwise requests wait in a bounded ``AdmissionQueue`` (FIFO, or
  shortest-prompt-first) and beyond ``queue_depth`` are rejected with a
  typed ``Overloaded`` result. Overload degrades into fast rejection, never
  into an unbounded backlog or a deadlock.
- **deadlines + cancellation** — a request whose deadline expires while
  queued is dropped before any engine work; one that expires mid-generation
  is cancelled via the engine's retire hook, its slot refilled on the next
  iteration, and the partial tokens are kept on the handle.
- **prefix cache** — admits consult an LRU of recent prefill caches
  (serve/prefix.py) and skip recomputing a shared prompt prefix.

The driver is synchronous and engine-agnostic, and delegates the
admit/prefill/decode *interleaving* to a ``Scheduler``
(serve/scheduler.py): this layer keeps the request-visible semantics
(handles, deadlines, terminal states), the scheduler decides when
admission work happens. With ``prefill_chunk`` set, a cold admit consumes
at most that many prompt tokens per ``step()`` — the slot sits in a
PREFILLING state (occupied, no tokens yet) between chunks while
co-resident slots keep decoding; token streams are byte-identical to
atomic admits. Both layers only use the engine's slot surface
(``free_slots`` / ``admit`` (or its ``begin_admit``/``continue_admit``
split) / ``decode_step`` / ``retire`` / ``cancel`` / ``slots``), which is
what lets the property suite drive the exact production code paths
against a pure-Python fake engine and a slot-state oracle — and why a
mesh-sharded ``ServeEngine`` (``sharding=ServeSharding(...)``,
serve/sharding.py) serves through this front-end unchanged: the slot
surface is placement-blind, so admission, deadlines and cancellation
compose with a model-split cache for free (the sharded fakes in
tests/test_serve_properties.py pin exactly this). ``AsyncServeFrontend``
is the thin asyncio skin: one driver task steps the shared engine, any
number of per-request streams multiplex over it.

Timing: the front-end owns a monotonic clock (injectable for tests — every
deadline decision is driven through ``clock()``, so expiry semantics are
deterministic under a manual clock even with a real engine underneath).
Tie-breaks are deliberate: a request that produces its final token on the
same step its deadline passes **completes** (the tokens exist; retiring
them as DONE dominates), while a deadline that passes at the admit boundary
**expires** before prefill (no engine work for a dead request).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.serve import errors
from repro.serve.engine import Request
from repro.serve.queue import Overloaded, Status, TERMINAL
from repro.serve.scheduler import Scheduler


@dataclasses.dataclass
class Handle:
    """Caller-facing view of one submitted request.

    ``tokens`` grows as the shared decode step produces tokens (streamed via
    ``ServeFrontend.stream`` or read directly); ``status`` moves through
    QUEUED/RUNNING into exactly one terminal state; ``result`` carries the
    typed ``Overloaded`` on rejection. Times are front-end clock seconds.
    """
    req: Request
    status: Status = Status.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    result: Optional[Overloaded] = None
    t_submit: float = 0.0
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def prompt_len(self) -> int:
        return len(self.req.tokens)

    @property
    def deadline(self) -> Optional[float]:
        return self.req.deadline

    @property
    def finished(self) -> bool:
        return self.status in TERMINAL

    @property
    def ttft(self) -> Optional[float]:
        """Submit -> first token (queue wait + prefill)."""
        return None if self.t_first is None else \
            self.t_first - self.t_submit

    @property
    def latency(self) -> Optional[float]:
        """Submit -> last token."""
        return None if self.t_done is None or self.t_first is None else \
            self.t_done - self.t_submit


class ServeFrontend:
    """Deterministic driver core: one ``step()`` = one engine iteration
    (expire -> resume chunked prefills -> admit -> decode -> retire).

    Parameters
    ----------
    engine       : a ``ServeEngine`` (or any object with its slot surface).
    queue_depth  : bounded waiting room beyond the slots; 0 disables
                   queueing entirely (admit-or-reject).
    policy       : "fifo" | "spf" (shortest-prompt-first admission).
    prefix_cache : optional ``PrefixCache`` consulted on every admit.
    prefill_chunk: max prompt tokens one admit consumes per ``step()``
                   (serve/scheduler.py); None = atomic whole-prompt admits.
    clock        : zero-arg callable returning seconds; defaults to a
                   monotonic clock anchored at construction.
    """

    def __init__(self, engine, *, queue_depth: int = 16,
                 policy: str = "fifo", prefix_cache=None, clock=None,
                 prefill_chunk: Optional[int] = None):
        self.engine = engine
        self.prefix_cache = prefix_cache
        if prefix_cache is not None and not engine.prefix_eligible():
            raise ValueError(errors.msg("prefix_ineligible",
                                        name=engine.cfg.name))
        self.scheduler = Scheduler(engine, prefill_chunk=prefill_chunk,
                                   queue_depth=queue_depth, policy=policy,
                                   prefix_cache=prefix_cache)
        self.queue = self.scheduler.queue
        if clock is None:
            t0 = time.perf_counter()
            clock = lambda: time.perf_counter() - t0  # noqa: E731
        self.clock = clock
        self.handles: dict = {}            # rid -> Handle
        self._by_slot: dict = {}           # engine slot -> running Handle
        engine.begin(getattr(engine, "_t0", None) or time.perf_counter())

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> Handle:
        """Register a request: admit now if a slot is free and nothing is
        waiting (FIFO fairness), queue it otherwise, reject with a typed
        ``Overloaded`` when the queue is full."""
        if req.rid in self.handles:
            raise ValueError(f"duplicate rid {req.rid}")
        h = Handle(req=req, t_submit=self.clock())
        self.handles[req.rid] = h
        if not len(self.queue) and self.engine.free_slots():
            self._admit(h, self.engine.free_slots()[0])
        elif not self.queue.push(h):
            h.result = Overloaded(rid=req.rid, queue_depth=self.queue.depth)
            self._finish(h, Status.REJECTED)
        return h

    def cancel(self, rid: int) -> bool:
        """Explicit caller cancel: drop a queued request before any engine
        work, or cancel a running one keeping its partial tokens. False if
        the request is unknown or already finished."""
        h = self.handles.get(rid)
        if h is None or h.finished:
            return False
        if h.status is Status.QUEUED:
            self.queue.remove(h)
            self._finish(h, Status.CANCELLED)
            return True
        slot = next(s for s, hh in self._by_slot.items() if hh is h)
        h.tokens = [int(t) for t in self.engine.cancel(slot)]
        self.scheduler.release(slot)
        del self._by_slot[slot]
        self._finish(h, Status.CANCELLED)
        return True

    # -- the scheduling step ------------------------------------------------

    def step(self) -> bool:
        """One engine iteration; returns True while work remains."""
        now = self.clock()
        # 0. fleet failures: a routing engine surfaces requests whose
        #    replica died with no survivor to re-dispatch to
        self._reap_failed()
        # 1. queued deadline expiry: never touches the engine
        for h in self.queue.take_expired(now):
            self._finish(h, Status.EXPIRED)
        # 2. running deadline expiry: retire hook frees the slot mid-flight.
        #    A slot expiring mid-chunked-prefill discards the partial
        #    prefill outright — zero tokens kept, slot refillable below
        for slot, h in list(self._by_slot.items()):
            if h.deadline is not None and now >= h.deadline:
                h.tokens = [int(t) for t in self.engine.cancel(slot)]
                self.scheduler.release(slot)
                del self._by_slot[slot]
                self._finish(h, Status.EXPIRED)
        # 3. resume in-flight chunked prefills: one chunk per slot per step
        #    (slots finishing their prompt join this step's decode)
        for slot in self.scheduler.advance():
            self._installed(self._by_slot[slot], slot)
        # 4. refill free slots from the queue (policy order)
        while len(self.queue):
            free = self.engine.free_slots()
            free = [s for s in free if s not in self._by_slot]
            if not free:
                break
            self._admit(self.queue.pop(), free[0])
        # 5. one shared decode step; stream tokens out, retire the finished
        if self.scheduler.should_decode():
            retired = self.engine.decode_step()
            for slot, h in self._by_slot.items():
                h.tokens = [int(t) for t in self.engine.slots[slot].out]
            for slot in retired:
                h = self._by_slot.pop(slot)
                comp = self.engine.retire(slot)
                h.tokens = [int(t) for t in comp.tokens]
                self._finish(h, Status.DONE)
            self._reap_failed()       # decode may have killed a replica
        return bool(self._by_slot) or len(self.queue) > 0

    def _reap_failed(self):
        """Finish FAILED any request a fleet engine reports as lost (its
        replica died, no survivor absorbed the re-dispatch). Partial
        tokens are kept; exactly-once like every other terminal. Engines
        without a ``take_failed`` surface (the single-engine case) are
        untouched."""
        take = getattr(self.engine, "take_failed", None)
        if take is None:
            return
        for slot, tokens in take():
            self.scheduler.release(slot)
            h = self._by_slot.pop(slot, None)
            if h is not None and not h.finished:
                h.tokens = [int(t) for t in tokens]
                self._finish(h, Status.FAILED)

    def _admit(self, h: Handle, slot: int):
        now = self.clock()
        if h.deadline is not None and now >= h.deadline:
            # expired exactly at the admit boundary: no prefill for a
            # request nobody is waiting on
            self._finish(h, Status.EXPIRED)
            return
        if not self.scheduler.start(h.req, slot):
            # chunked prefill under way: the slot is occupied (PREFILLING)
            # but no token exists yet — t_first waits for installation
            h.status = Status.RUNNING
            h.t_admit = self.clock()
            self._by_slot[slot] = h
            return
        self._installed(h, slot)

    def _installed(self, h: Handle, slot: int):
        """Prefill finished — atomically at admit, or on the last chunk —
        and the first token exists on the slot. gen==1 retires right here;
        a deadline that elapsed during prefill keeps the prefill token and
        frees the slot before it ever decodes."""
        h.status = Status.RUNNING
        t = self.clock()
        if h.t_admit is None:          # atomic admit: t_admit == t_first
            h.t_admit = t
        h.t_first = t
        h.tokens = [int(tk) for tk in self.engine.slots[slot].out]
        if self.engine.slots[slot].remaining == 0:
            self._by_slot.pop(slot, None)
            self.engine.retire(slot)         # gen==1 completes at admit
            self._finish(h, Status.DONE)
        elif h.deadline is not None and self.clock() >= h.deadline:
            h.tokens = [int(tk) for tk in self.engine.cancel(slot)]
            self._by_slot.pop(slot, None)
            self._finish(h, Status.EXPIRED)
        else:
            self._by_slot[slot] = h

    def _finish(self, h: Handle, status: Status):
        assert not h.finished, f"rid {h.rid} finalized twice"
        h.status = status
        h.t_done = self.clock()

    # -- streaming ----------------------------------------------------------

    def stream(self, h: Handle):
        """Incremental token iterator for one request: yields each token as
        soon as it exists, driving ``step()`` while waiting. Returns (ends
        the iterator) once the handle is terminal and drained — a rejected
        handle yields nothing, an expired one yields its partial tokens."""
        sent = 0
        while True:
            while sent < len(h.tokens):
                yield h.tokens[sent]
                sent += 1
            if h.finished:
                return
            self.step()

    # -- trace driver -------------------------------------------------------

    def run(self, requests: List[Request], *, log=None) -> List[Handle]:
        """Serve a trace (arrival-timed, like ``ServeEngine.run``) through
        the full front-end; returns handles in rid order."""
        t_anchor = self.clock()
        # trace deadlines are absolute *trace* seconds; rebase them onto
        # this run's clock anchor so step()'s comparisons line up
        pending = [r if r.deadline is None else
                   dataclasses.replace(r, deadline=r.deadline + t_anchor)
                   for r in sorted(requests,
                                   key=lambda r: (r.arrival, r.rid))]
        i = 0
        while i < len(pending) or any(not h.finished
                                      for h in self.handles.values()):
            now = self.clock() - t_anchor
            while i < len(pending) and pending[i].arrival <= now:
                h = self.submit(pending[i])
                if log and h.status is Status.REJECTED:
                    log(f"[frontend] rid={h.rid} rejected ({h.result})")
                i += 1
            busy = self.step()
            if not busy and i < len(pending):
                time.sleep(max(0.0, min(
                    pending[i].arrival - (self.clock() - t_anchor), 1e-3)))
        return [self.handles[r.rid] for r in
                sorted(requests, key=lambda r: r.rid)]


# ---------------------------------------------------------------------------
# asyncio layer
# ---------------------------------------------------------------------------

class AsyncServeFrontend:
    """asyncio skin over ``ServeFrontend``: one driver task steps the shared
    engine; each request is an independent async token stream.

    >>> afe = AsyncServeFrontend(frontend)            # doctest: +SKIP
    >>> async def consume(req):
    ...     return [tok async for tok in afe.stream(await afe.submit(req))]

    Concurrent ``consume``s interleave: every driver step wakes all waiting
    streams, so each request's tokens surface as its slot produces them —
    the decode step stays shared, only the waiting is multiplexed.
    """

    def __init__(self, frontend: ServeFrontend):
        import asyncio
        self._asyncio = asyncio
        self.frontend = frontend
        self._task = None
        self._wake = asyncio.Event()

    def _ensure_driver(self):
        if self._task is None or self._task.done():
            # fresh, unset wake: a dead driver leaves _wake permanently
            # set (its exit-path release), and Event.wait() on a set
            # event returns without yielding — a stream polling it would
            # livelock the loop and the new driver task would never run
            self._wake = self._asyncio.Event()
            self._task = self._asyncio.ensure_future(self._drive())

    async def _drive(self):
        # terminate on `not busy` alone: once the queue is empty and no
        # slot is occupied there is nothing left to drive — in particular
        # every handle reaching a terminal state implies it. The previous
        # condition additionally required all handles finished, so any
        # handle stranded outside queue/slots (or registered externally)
        # left this task spinning forever: a leak, regression-tested via
        # task introspection in tests/test_serve_frontend.py. A later
        # submit restarts the driver (_ensure_driver checks task.done()).
        try:
            while True:
                busy = self.frontend.step()
                self._wake.set()
                self._wake = self._asyncio.Event()
                await self._asyncio.sleep(0)
                if not busy:
                    return
        finally:
            self._wake.set()       # release any stragglers

    async def submit(self, req: Request) -> Handle:
        h = self.frontend.submit(req)
        self._ensure_driver()
        return h

    async def stream(self, h: Handle):
        """Async token iterator; yields between engine iterations."""
        sent = 0
        while True:
            while sent < len(h.tokens):
                yield h.tokens[sent]
                sent += 1
            if h.finished:
                return
            self._ensure_driver()
            await self._wake.wait()


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------

def frontend_table(handles: List[Handle], wall: float) -> dict:
    """Outcome counts + latency percentiles over the served (DONE) subset."""
    by = {s: [h for h in handles if h.status is s] for s in Status}
    done = by[Status.DONE]
    out = {
        "requests": len(handles),
        "done": len(done),
        "rejected": len(by[Status.REJECTED]),
        "expired": len(by[Status.EXPIRED]),
        "cancelled": len(by[Status.CANCELLED]),
        "failed": len(by[Status.FAILED]),
        "tokens": int(sum(len(h.tokens) for h in handles)),
        "wall_s": wall,
        "tok_per_s": sum(len(h.tokens) for h in handles) / max(wall, 1e-9),
    }
    if done:
        lat = np.asarray([h.latency for h in done])
        ttft = np.asarray([h.ttft for h in done])
        out.update(
            lat_p50_ms=float(np.percentile(lat, 50)) * 1e3,
            lat_p99_ms=float(np.percentile(lat, 99)) * 1e3,
            ttft_p50_ms=float(np.percentile(ttft, 50)) * 1e3,
            ttft_p99_ms=float(np.percentile(ttft, 99)) * 1e3,
        )
    return out
