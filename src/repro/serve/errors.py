"""Single source of truth for serving-stack rejection messages.

Every ``ValueError`` the serving tier raises on a *refused configuration or
request* formats its message from this table. Tests that assert on refusal
wording (the ``test_serve_zoo`` xfail matrix, ``pytest.raises(match=...)``
checks) build their expectations from the same entries, so the engine and
the tests cannot drift apart: renaming a message here updates both sides,
and ``tests/test_serve_errors.py`` fails if any test file re-inlines a
message as a string literal.

Keys name the *refusal*, not the call site — several call sites share one
entry (e.g. the engine's warmup and the front-end both refuse an ineligible
prefix cache with ``prefix_ineligible``).
"""
from __future__ import annotations

ERRORS = {
    # engine construction / admission
    "no_serving_path":
        "{name}: family {family!r} has no serving path",
    "encdec_needs_mem_len":
        "encdec serving needs mem_len= (fixed encoder memory length)",
    "prompt_exceeds_bucket":
        "prompt length {n} exceeds largest bucket {bucket}",
    "request_exceeds_max_len":
        "request {rid}: prompt {prompt} + gen {gen} exceeds max_len "
        "{max_len}",
    "frames_mem_len_mismatch":
        "request {rid}: frames length {frames} != mem_len {mem_len}",
    "cancel_free_slot":
        "cancel on free slot {slot}",
    # prefix reuse: sound only under a replayable slot-cache contract
    # (docs/serving.md "Slot-cache contracts")
    "prefix_ineligible":
        "{name}: prefix cache needs a replayable slot-cache contract "
        "(pure global-attention KV rewind, or whole-prefix recurrent "
        "state snapshots); serve without one",
    "static_trace_ineligible":
        "static ragged baseline needs a pure global-attention stack "
        "(batched ragged prefill)",
    # mesh-sharded serving: every payload leaf of the slot cache must
    # split evenly over the model axis (never padded — docs/serving.md
    # "Mesh-sharded serving")
    "shard_ineligible":
        "{name}: slot-cache leaf {leaf!r} has no model-axis dim divisible "
        "by the {m}-way model axis; serve unsharded or re-mesh",
    # scheduler: chunked-prefill policy (serve/scheduler.py)
    "chunk_invalid":
        "prefill chunk must be a positive token budget, got {chunk}",
    "chunk_unsupported":
        "{name}: chunked prefill needs the non-atomic begin_admit/"
        "continue_admit slot surface; serve with prefill_chunk=None",
    "continue_without_begin":
        "continue_admit on slot {slot}: no admit in progress "
        "(begin_admit first)",
    # fleet routing
    "router_needs_engines":
        "ReplicaRouter needs at least one engine",
    "unknown_route":
        "unknown route {route!r}; known: {routes}",
    "affinity_ineligible":
        "{name}: prefix-affinity routing needs a replayable slot-cache "
        "contract (pure global-attention KV rewind, or whole-prefix "
        "recurrent state snapshots); route least-loaded instead",
}


def msg(key: str, **kw) -> str:
    """Format the rejection message for ``key`` (raises KeyError on an
    unknown key and on a stale placeholder, so call sites can't silently
    diverge from the table)."""
    return ERRORS[key].format(**kw)
