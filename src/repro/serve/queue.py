"""Admission control for the serving front-end: bounded queue + backpressure.

When every engine slot is occupied, incoming requests wait here — FIFO by
default, shortest-prompt-first with ``policy="spf"`` (the scheduling knob
the ROADMAP asks for: short prompts prefill cheaply and free their slot
sooner, cutting p50 ttft at a bounded fairness cost). The queue is bounded:
beyond ``depth`` waiting requests the front-end stops accepting and rejects
with a typed :class:`Overloaded` result instead of growing an unbounded
backlog — overload must surface as fast failure, not as unbounded latency.

Deadlines are enforced *in the queue* too: a request whose deadline passes
while it waits is expired without ever touching the engine (no prefill work
for a request nobody is waiting on).

Pure Python, no jax — this module is the scheduling state machine the
property suite (``tests/test_serve_properties.py``) drives against a
slot-state oracle.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class Status(enum.Enum):
    """Lifecycle states of a front-end request.

    Exactly one terminal state is reached per request (property-tested):
    ``DONE`` (all ``gen`` tokens), ``REJECTED`` (queue full at submit,
    typed ``Overloaded`` result, zero engine work), ``EXPIRED`` (deadline
    passed — partial tokens are kept), ``CANCELLED`` (explicit caller
    cancel — partial tokens are kept), or ``FAILED`` (fleet serving only:
    the request's replica died and no survivor could absorb the
    re-dispatch — partial tokens are kept; see serve/router.py).
    """
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    REJECTED = "rejected"
    EXPIRED = "expired"
    CANCELLED = "cancelled"
    FAILED = "failed"


TERMINAL = frozenset((Status.DONE, Status.REJECTED, Status.EXPIRED,
                      Status.CANCELLED, Status.FAILED))


@dataclasses.dataclass(frozen=True)
class Overloaded:
    """Typed backpressure result: the bounded queue was full at submit.

    Carried on the rejected handle's ``result`` so callers can distinguish
    "shed under overload" (retry elsewhere / later) from a served-but-failed
    request without parsing strings.
    """
    rid: int
    queue_depth: int

    def __str__(self):
        return (f"request {self.rid} rejected: queue full "
                f"(depth {self.queue_depth})")


class AdmissionQueue:
    """Bounded waiting room between ``submit`` and a free engine slot.

    Items must expose ``prompt_len`` and ``deadline`` attributes (the
    front-end queues its request handles). ``push`` refuses items beyond
    ``depth`` — the caller turns that into an :class:`Overloaded` result.

    ``policy``:
      - ``"fifo"`` — strict arrival order.
      - ``"spf"`` — shortest-prompt-first: ``pop`` picks the waiting item
        with the fewest prompt tokens (ties broken by arrival order, so
        equal-length requests stay FIFO).
    """

    POLICIES = ("fifo", "spf")

    def __init__(self, depth: int, policy: str = "fifo"):
        if depth < 0:
            raise ValueError(f"queue depth must be >= 0, got {depth}")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown queue policy {policy!r}; "
                             f"known: {self.POLICIES}")
        self.depth, self.policy = depth, policy
        self._items: List = []

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    @property
    def full(self) -> bool:
        return len(self._items) >= self.depth

    def push(self, item) -> bool:
        """Enqueue ``item``; False (and no side effect) when full."""
        if self.full:
            return False
        self._items.append(item)
        return True

    def pop(self):
        """Next item to admit under the configured policy."""
        if not self._items:
            raise IndexError("pop from empty AdmissionQueue")
        if self.policy == "spf":
            i = min(range(len(self._items)),
                    key=lambda j: self._items[j].prompt_len)
        else:
            i = 0
        return self._items.pop(i)

    def take_expired(self, now: float) -> List:
        """Remove and return every waiting item whose deadline has passed
        (``deadline <= now``); queue order of the survivors is preserved."""
        expired = [it for it in self._items
                   if it.deadline is not None and it.deadline <= now]
        if expired:
            self._items = [it for it in self._items
                           if not (it.deadline is not None
                                   and it.deadline <= now)]
        return expired

    def remove(self, item) -> bool:
        """Remove a specific waiting item (explicit cancel); False if the
        item is not queued."""
        try:
            self._items.remove(item)
            return True
        except ValueError:
            return False
