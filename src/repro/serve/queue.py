"""Front-end request lifecycle types + typed backpressure result.

The admission *policies* (the bounded FIFO/shortest-prompt-first waiting
room, deadline expiry in the queue) are scheduler-owned since the
chunked-prefill PR: :class:`~repro.serve.scheduler.AdmissionQueue` lives in
``serve/scheduler.py`` next to the interleaving policy that drives it, and
is re-exported here so existing imports keep working. What remains in this
module is the request-visible state machine: the :class:`Status` lifecycle
(exactly one terminal per request, property-tested) and the typed
:class:`Overloaded` rejection the bounded queue degrades into — overload
must surface as fast failure, not as unbounded latency.

Pure Python, no jax.
"""
from __future__ import annotations

import dataclasses
import enum


class Status(enum.Enum):
    """Lifecycle states of a front-end request.

    Exactly one terminal state is reached per request (property-tested):
    ``DONE`` (all ``gen`` tokens), ``REJECTED`` (queue full at submit,
    typed ``Overloaded`` result, zero engine work), ``EXPIRED`` (deadline
    passed — partial tokens are kept), ``CANCELLED`` (explicit caller
    cancel — partial tokens are kept), or ``FAILED`` (fleet serving only:
    the request's replica died and no survivor could absorb the
    re-dispatch — partial tokens are kept; see serve/router.py).
    """
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    REJECTED = "rejected"
    EXPIRED = "expired"
    CANCELLED = "cancelled"
    FAILED = "failed"


TERMINAL = frozenset((Status.DONE, Status.REJECTED, Status.EXPIRED,
                      Status.CANCELLED, Status.FAILED))


@dataclasses.dataclass(frozen=True)
class Overloaded:
    """Typed backpressure result: the bounded queue was full at submit.

    Carried on the rejected handle's ``result`` so callers can distinguish
    "shed under overload" (retry elsewhere / later) from a served-but-failed
    request without parsing strings.
    """
    rid: int
    queue_depth: int

    def __str__(self):
        return (f"request {self.rid} rejected: queue full "
                f"(depth {self.queue_depth})")


# back-compat re-export: the admission policies moved into the scheduling
# layer (see module docstring); import at the bottom so the annotation
# types above exist before scheduler-side consumers resolve this module
from repro.serve.scheduler import AdmissionQueue  # noqa: E402,F401
