"""LRU prefix cache: reuse prefill KV across requests sharing a prompt prefix.

The common production case is a long shared system prompt followed by a
short user-specific suffix. A cold admit prefills the whole prompt; this
cache keeps the batch-1 prefill cache pytrees of recent prompts so a later
request whose prompt *starts with* a cached prompt's tokens can skip
recomputing that prefix entirely.

Exactness argument (same as ragged prefill, docs/serving.md): in a pure
causal global-attention stack, cache row ``i`` depends only on tokens
``<= i``. Any prefix of a cached prompt's rows is therefore *exactly* the
cache a fresh prefill of that prefix would produce — reuse is a ``pos``
rewind (``override_cache_pos`` to the hit length; stale rows beyond it are
masked by ``key_idx <= pos`` and overwritten as decode proceeds), followed
by per-token decode steps over only the un-cached suffix. Sliding-window
ring buffers violate the row-locality premise, so the engine only consults
this cache on replayable contracts (docs/serving.md "Slot-cache
contracts"); recurrent stacks use it in *whole-entry* mode — their entries
are state snapshots, reusable as-is but never rewindable
(``usable_prefix_len``).

Entries are whole device-resident cache pytrees (``(1, max_len, ...)`` per
leaf), so capacity is small and LRU: ``cap`` entries, least-recently-hit
evicted first. All jax arrays are immutable — handing a cached pytree to
the (non-donating) suffix decode can never corrupt the entry.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class PrefixEntry:
    tokens: np.ndarray        # (P,) int32 — the prompt this cache prefilled
    cache: object             # batch-1 prefill cache pytree (device arrays)
    nbytes: int


def common_prefix_len(a: np.ndarray, b: np.ndarray) -> int:
    """Length of the longest common prefix of two 1-D token arrays."""
    n = min(len(a), len(b))
    if n == 0:
        return 0
    neq = np.nonzero(a[:n] != b[:n])[0]
    return int(neq[0]) if len(neq) else n


def usable_prefix_len(entry_tokens: np.ndarray, tokens: np.ndarray,
                      whole_entry: bool = False) -> int:
    """How many leading ``tokens`` an entry for ``entry_tokens`` covers.

    Capped at ``len(tokens) - 1`` so at least one prompt token always runs
    through the model (its logits produce the first generated token).

    ``whole_entry=True`` is the *recurrent* contract: a KV cache can be
    rewound to any row (row ``i`` is a pure function of tokens ``<= i``),
    but a recurrent state is one lossy summary of everything the entry
    consumed — it is reusable only as-is, i.e. when the entry's full prompt
    is a proper prefix of the new one. Partial overlaps return 0.
    """
    L = min(common_prefix_len(entry_tokens, tokens), len(tokens) - 1)
    if whole_entry and L < len(entry_tokens):
        return 0
    return L


class PrefixCache:
    """LRU over recent prefill caches, looked up by longest shared prefix.

    ``min_hit`` is the smallest reusable prefix worth taking: a 1-token hit
    saves one prefill position but costs a cache scan, so tiny overlaps are
    treated as misses.
    """

    def __init__(self, cap: int = 8, min_hit: int = 4):
        if cap <= 0:
            raise ValueError(f"prefix cache cap must be > 0, got {cap}")
        self.cap, self.min_hit = cap, min_hit
        self._entries: "collections.OrderedDict[bytes, PrefixEntry]" = \
            collections.OrderedDict()
        self.hits = self.misses = self.evictions = 0
        self.reused_tokens = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    @staticmethod
    def _key(tokens: np.ndarray) -> bytes:
        return np.asarray(tokens, np.int32).tobytes()

    def lookup(self, tokens,
               whole_entry: bool = False) -> Optional[Tuple[PrefixEntry, int]]:
        """Best reusable entry for a new prompt, or None.

        Returns ``(entry, L)`` with ``L`` the number of leading prompt
        tokens covered by the entry — capped at ``len(tokens) - 1`` so at
        least one prompt token always runs through the model (its logits
        produce the first generated token). ``whole_entry=True`` restricts
        matches to entries fully covered by the prompt (the recurrent
        state-snapshot contract, see ``usable_prefix_len``). Counts a
        hit/miss and refreshes the hit entry's LRU position.
        """
        tokens = np.asarray(tokens, np.int32)
        best, best_key, best_len = None, None, 0
        for key, e in self._entries.items():
            L = usable_prefix_len(e.tokens, tokens, whole_entry)
            if L > best_len:
                best, best_key, best_len = e, key, L
        if best is None or best_len < self.min_hit:
            self.misses += 1
            return None
        self.hits += 1
        self.reused_tokens += best_len
        self._entries.move_to_end(best_key)
        return best, best_len

    def insert(self, tokens, cache, nbytes: int):
        """Remember ``cache`` as the prefill of ``tokens`` (LRU evict)."""
        tokens = np.asarray(tokens, np.int32)
        key = self._key(tokens)
        if key in self._entries:            # refresh, don't duplicate
            self._entries.move_to_end(key)
            return
        self._entries[key] = PrefixEntry(tokens=tokens, cache=cache,
                                         nbytes=nbytes)
        while len(self._entries) > self.cap:
            self._entries.popitem(last=False)
            self.evictions += 1

    def stats(self) -> dict:
        return {"entries": len(self), "bytes": self.bytes, "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "reused_tokens": self.reused_tokens}
