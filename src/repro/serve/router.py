"""Multi-replica routed serving: N engines behind one slot surface.

A single ``ServeEngine`` is one host's worth of slots. CORP's serving
claim is fleet-level: pruned models shrink the per-slot KV cache
(``eff_qk``), so a host holds more slots and a fleet holds more replicas —
but that win only materializes if the serving tier can spread traffic
across engines. ``ReplicaRouter`` does that routing while *speaking the
same engine-agnostic slot surface the front-end already consumes*
(``free_slots`` / ``admit`` / ``decode_step`` / ``retire`` / ``cancel`` /
``begin`` / ``slots`` / ``active_count``, plus the non-atomic
``begin_admit`` / ``continue_admit`` / ``decoding_count`` split the
scheduler's chunked-prefill policy drives), so ``ServeFrontend`` and
``AsyncServeFrontend`` layer on top of a fleet exactly as they layer on
one engine (docs/serving.md "Multi-replica routing"). A replica dying
mid-chunked-prefill re-dispatches like any other orphan: its virtual slot
has delivered zero tokens, so the survivor re-prefills from the prompt —
greedy determinism keeps the stream exact.

Design: **virtual slots**. The router exposes ``sum(n_slots)`` virtual
slot ids. The front-end admits into a virtual id; the router *binds* it to
a concrete ``(replica, physical slot)`` chosen by the routing policy at
admit time:

- ``least-loaded`` — the UP replica with the fewest occupied physical
  slots (deterministic tie-break: lowest replica index, then lowest local
  slot — the fleet property suite pins this argmin against an oracle).
- ``prefix-affinity`` — the UP replica whose per-replica ``PrefixCache``
  holds the longest prefix of the request's prompt (ties and misses fall
  back to least-loaded). Affinity is self-reinforcing: the admit inserts
  the new prefill into the chosen replica's cache.

One router ``decode_step`` steps every live replica **concurrently**
(one thread per replica — each replica's jitted step holds no shared
state, and device compute releases the GIL), which is where the fleet
throughput win comes from: N replicas' decode steps cost one replica's
wall time, gated >= 3x for N=4 in ``benchmarks/bench_serve.py``.

Health: a replica whose ``decode_step``/``admit`` raises is marked DOWN.
Its in-flight requests keep every token produced before the failing step
(the router mirrors tokens into the virtual slot after each successful
step) and are **re-dispatched** to survivors: greedy decode is
deterministic, so re-prefilling ``prompt + tokens[:-1]`` on a survivor
reproduces the stream exactly from the failure point — no token loss, no
duplicates. With no survivors the request is finished ``FAILED``
exactly-once (the front-end reaps ``take_failed()``).

``drain(replica)`` stops new admissions (including re-dispatches) to a
replica while its in-flight requests run to completion; ``drained()``
reports when it is removable.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import time
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional

import numpy as np

from repro.serve import errors
from repro.serve.engine import Completion, Request
from repro.serve.prefix import PrefixCache, usable_prefix_len

ROUTES = ("least-loaded", "prefix-affinity")


class ReplicaState(enum.Enum):
    UP = "up"
    DRAINING = "draining"
    DOWN = "down"


class _VState(enum.Enum):
    FREE = "free"          # admittable
    BOUND = "bound"        # live on a (replica, pslot)
    PENDING = "pending"    # replica died; awaiting re-dispatch
    FAILED = "failed"      # no survivor; awaiting take_failed()


@dataclasses.dataclass
class _VSlot:
    """Router-side view of one request: the canonical token stream and
    the current physical binding (if any). ``base`` is the global token
    index of the bound replica's ``out[0]`` — 0 on first admit, and the
    re-dispatch overlap offset afterwards (the survivor's re-prefill
    token duplicates the last token already delivered)."""
    state: _VState = _VState.FREE
    rid: int = -1
    req: Optional[Request] = None
    out: list = dataclasses.field(default_factory=list)
    remaining: int = 0
    replica: int = -1
    pslot: int = -1
    base: int = 0
    t_admit: float = 0.0

    @property
    def free(self) -> bool:
        return self.state is _VState.FREE


class _Replica:
    def __init__(self, engine):
        self.engine = engine
        self.state = ReplicaState.UP

    @property
    def up(self) -> bool:
        return self.state is ReplicaState.UP

    @property
    def live(self) -> bool:
        return self.state is not ReplicaState.DOWN


class ReplicaRouter:
    """Load-balance N engine instances behind one engine-shaped surface.

    Parameters
    ----------
    engines    : list of ``ServeEngine``-surface objects (same model).
    route      : "least-loaded" | "prefix-affinity".
    prefix_cap : per-replica prefix-cache capacity for prefix-affinity
                 routing (defaults to 8 when the route needs caches;
                 ignored for least-loaded).
    min_hit    : smallest prefix overlap that counts as affinity.
    """

    def __init__(self, engines: List, *, route: str = "least-loaded",
                 prefix_cap: int = 0, min_hit: int = 4):
        if not engines:
            raise ValueError(errors.msg("router_needs_engines"))
        if route not in ROUTES:
            raise ValueError(errors.msg("unknown_route", route=route,
                                        routes=ROUTES))
        self.route = route
        self.replicas = [_Replica(e) for e in engines]
        # recurrent replicas hold state snapshots, reusable whole-entry
        # only (serve/prefix.py) — affinity must score them the same way
        self._whole_entry = getattr(engines[0], "contract",
                                    "kv") == "recurrent"
        self._caches: Optional[List[PrefixCache]] = None
        if route == "prefix-affinity":
            if not engines[0].prefix_eligible():
                raise ValueError(errors.msg("affinity_ineligible",
                                            name=engines[0].cfg.name))
            self._caches = [PrefixCache(cap=prefix_cap or 8,
                                        min_hit=min_hit)
                            for _ in engines]
        # virtual slot table: gid -> (replica, local slot) bindings happen
        # at admit time; gids themselves are stable across re-dispatch
        self.vslots = [_VSlot()
                       for _ in range(sum(e.n_slots for e in engines))]
        self._pending: collections.deque = collections.deque()  # gids
        self._failed: list = []             # (gid, tokens) for take_failed
        self._prefilling: set = set()       # gids mid-chunked-prefill
        self._pool = ThreadPoolExecutor(
            max_workers=len(engines),
            thread_name_prefix="replica-decode")
        self.rstats = collections.Counter()
        self._t0 = None

    # -- engine-agnostic slot surface (what the front-end consumes) --------

    @property
    def cfg(self):
        return self.replicas[0].engine.cfg

    @property
    def n_slots(self) -> int:
        return len(self.vslots)

    @property
    def slots(self) -> List[_VSlot]:
        return self.vslots

    def prefix_eligible(self) -> bool:
        return self.replicas[0].engine.prefix_eligible()

    def begin(self, t0: Optional[float] = None):
        self._t0 = time.perf_counter() if t0 is None else t0
        for r in self.replicas:
            r.engine.begin(self._t0)

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _capacity(self) -> int:
        """Free physical slots on UP replicas, minus the seats reserved
        for orphans awaiting re-dispatch (orphans have priority)."""
        free = sum(len(r.engine.free_slots())
                   for r in self.replicas if r.up)
        return max(0, free - len(self._pending))

    def free_slots(self) -> List[int]:
        """Admittable virtual ids, capacity-limited to the fleet's free
        physical slots (the binding itself happens at admit time)."""
        cap = self._capacity()
        if cap <= 0:
            return []
        return [g for g, v in enumerate(self.vslots) if v.free][:cap]

    def active_count(self) -> int:
        return sum(v.state in (_VState.BOUND, _VState.PENDING)
                   for v in self.vslots)

    def decoding_count(self) -> int:
        """Virtual slots a decode step can serve: BOUND slots past their
        prefill, plus orphans awaiting re-dispatch (stepping the fleet is
        what re-dispatches them). PREFILLING slots are excluded — they
        advance via ``continue_admit``, not decode lanes."""
        return sum(1 for g, v in enumerate(self.vslots)
                   if (v.state is _VState.BOUND
                       and g not in self._prefilling)
                   or v.state is _VState.PENDING)

    # -- routing policy -----------------------------------------------------

    def _candidates(self) -> List[int]:
        """UP replicas with at least one free physical slot, least-loaded
        first (tie-break: replica index — the oracle-pinned argmin)."""
        cand = [i for i, r in enumerate(self.replicas)
                if r.up and r.engine.free_slots()]
        return sorted(cand,
                      key=lambda i: (self.replicas[i].engine.active_count(),
                                     i))

    def _choose(self, req: Request) -> Optional[int]:
        cand = self._candidates()
        if not cand:
            return None
        if self._caches is not None:
            # longest cached prefix wins; peek without counting a miss so
            # the fallback path doesn't skew per-replica hit stats
            toks = np.asarray(req.tokens, np.int32)
            best, best_len = None, 0
            for i in cand:
                for e in self._caches[i]._entries.values():
                    L = usable_prefix_len(e.tokens, toks,
                                          self._whole_entry)
                    if L >= self._caches[i].min_hit and L > best_len:
                        best, best_len = i, L
            if best is not None:
                self.rstats["affinity_hits"] += 1
                return best
        return cand[0]

    # -- admission ----------------------------------------------------------

    def admit(self, req: Request, slot: int, prefix_cache=None):
        """Route ``req`` to a replica chosen by policy and bind it to
        virtual id ``slot``. A replica that raises during prefill is
        marked DOWN and the admit retries on the next survivor; with no
        survivors the request is registered FAILED (reaped via
        ``take_failed`` — this method never raises on replica death)."""
        v = self.vslots[slot]
        assert v.free, f"admit into non-free virtual slot {slot}"
        v.state, v.rid, v.req = _VState.BOUND, req.rid, req
        v.out, v.remaining, v.base = [], req.gen, 0
        v.t_admit = self._now() if self._t0 is not None else 0.0
        if not self._bind(slot, req, prefix_cache=prefix_cache):
            # every replica died under us: FAILED, exactly-once, no raise
            v.state = _VState.FAILED
            self._failed.append(slot)
            self.rstats["failed"] += 1
        self.rstats["routed_admits"] += 1

    def begin_admit(self, req: Request, slot: int, prefix_cache=None):
        """Non-atomic admit surface (serve/scheduler.py chunked prefill):
        bind virtual id ``slot`` and ``begin_admit`` on a policy-chosen
        replica — no prefill work yet. Death handling matches ``admit``:
        retries on survivors, FAILED with none left, never raises."""
        v = self.vslots[slot]
        assert v.free, f"admit into non-free virtual slot {slot}"
        v.state, v.rid, v.req = _VState.BOUND, req.rid, req
        v.out, v.remaining, v.base = [], req.gen, 0
        v.t_admit = self._now() if self._t0 is not None else 0.0
        if self._bind(slot, req, prefix_cache=prefix_cache, begin=True):
            self._prefilling.add(slot)
        else:
            v.state = _VState.FAILED
            self._failed.append(slot)
            self.rstats["failed"] += 1
        self.rstats["routed_admits"] += 1

    def continue_admit(self, slot: int,
                       budget: Optional[int] = None) -> bool:
        """One chunk of prefill for virtual slot ``slot`` on its bound
        replica; True once its prompt is consumed (first token mirrored
        into the virtual stream). A replica dying mid-prefill orphans the
        slot with zero delivered tokens, so re-dispatch re-prefills from
        the prompt on a survivor — atomically, and greedy determinism
        keeps the stream byte-identical."""
        v = self.vslots[slot]
        if v.state is _VState.FAILED:
            return False                   # reaped via take_failed()
        if slot not in self._prefilling:
            return True    # already completed by an atomic re-dispatch
        if v.state is _VState.BOUND:
            try:
                done = self.replicas[v.replica].engine.continue_admit(
                    v.pslot, budget)
            except Exception:  # noqa: BLE001 - replica death is the point
                self._fail_replica(v.replica)
                done = None
            if done is not None:
                if not done:
                    return False
                self._prefilling.discard(slot)
                self._sync_vslot(slot)
                return True
        # PENDING (possibly just orphaned above): try a re-dispatch now —
        # decode lanes may all be prefilling, so waiting for decode_step's
        # re-dispatch could livelock. A successful re-dispatch prefills
        # the whole prompt atomically and clears the prefilling mark.
        self._redispatch()
        return slot not in self._prefilling and v.state is _VState.BOUND

    def _bind(self, gid: int, req: Request, prefix_cache=None,
              begin: bool = False) -> bool:
        """Admit ``req`` on a policy-chosen replica; retries across
        replica deaths. True on success (vslot bound + tokens synced).
        ``begin=True`` binds via the replica's ``begin_admit`` (no prefill
        work, nothing to sync yet)."""
        v = self.vslots[gid]
        while True:
            i = self._choose(req)
            if i is None:
                return False
            r = self.replicas[i]
            pslot = r.engine.free_slots()[0]
            cache = prefix_cache if prefix_cache is not None else (
                self._caches[i] if self._caches is not None else None)
            try:
                if begin:
                    r.engine.begin_admit(req, pslot, prefix_cache=cache)
                else:
                    r.engine.admit(req, pslot, prefix_cache=cache)
            except Exception:  # noqa: BLE001 - replica death is the point
                self._fail_replica(i)
                continue
            v.replica, v.pslot = i, pslot
            # physical out[0] is the re-prefill token, which duplicates
            # the last token already delivered (greedy determinism) — so
            # it maps to global index len(out)-1 on re-dispatch, 0 cold
            v.base = max(0, len(v.out) - 1)
            if not begin:
                self._sync_vslot(gid)
            return True

    # -- the shared decode step ---------------------------------------------

    def decode_step(self) -> List[int]:
        """Re-dispatch orphans, then step every live replica with active
        slots concurrently; returns completed *virtual* ids. A replica
        that raises is marked DOWN and its requests are orphaned with
        every token produced before the failing step."""
        self._redispatch()
        stepping = [i for i, r in enumerate(self.replicas)
                    if r.live and getattr(r.engine, "decoding_count",
                                          r.engine.active_count)()]
        if len(stepping) == 1:
            results = {stepping[0]: self._step_one(stepping[0])}
        else:
            futs = {i: self._pool.submit(self._step_one, i)
                    for i in stepping}
            results = {i: f.result() for i, f in futs.items()}
        for i in stepping:
            if isinstance(results[i], Exception):
                self._fail_replica(i)
        retired = []
        for gid, v in enumerate(self.vslots):
            if v.state is not _VState.BOUND or v.replica not in stepping:
                continue
            if self.replicas[v.replica].state is ReplicaState.DOWN:
                continue                    # orphaned by _fail_replica
            if gid in self._prefilling:
                continue                    # no tokens until install
            self._sync_vslot(gid)
            if v.remaining == 0:
                retired.append(gid)
        self.rstats["router_steps"] += 1
        return retired

    def _step_one(self, i: int):
        try:
            return self.replicas[i].engine.decode_step()
        except Exception as e:  # noqa: BLE001 - health boundary
            return e

    def _sync_vslot(self, gid: int):
        """Mirror the bound replica's newly produced tokens into the
        virtual slot's canonical stream (skipping the re-dispatch
        overlap) and recompute remaining."""
        v = self.vslots[gid]
        phys = self.replicas[v.replica].engine.slots[v.pslot].out
        have = len(v.out) - v.base          # phys tokens already mirrored
        if have >= 1 and phys:
            # the re-prefill token must reproduce the stream (greedy
            # determinism); a mismatch would be silent corruption
            assert int(phys[0]) == int(v.out[v.base]), (
                f"rid {v.rid}: re-dispatch token {int(phys[0])} != "
                f"delivered {int(v.out[v.base])}")
        v.out.extend(int(t) for t in phys[have:])
        v.remaining = v.req.gen - len(v.out)

    # -- health: death, orphaning, re-dispatch ------------------------------

    def _fail_replica(self, i: int):
        r = self.replicas[i]
        if r.state is ReplicaState.DOWN:
            return
        r.state = ReplicaState.DOWN
        self.rstats["replicas_down"] += 1
        for gid, v in enumerate(self.vslots):
            if v.state is _VState.BOUND and v.replica == i:
                v.state = _VState.PENDING
                v.replica = v.pslot = -1
                self._pending.append(gid)
                self.rstats["orphaned"] += 1

    def kill(self, i: int):
        """Fault injection / ops: mark replica ``i`` DOWN now and orphan
        its in-flight requests (idempotent)."""
        self._fail_replica(i)

    def _redispatch(self):
        """Re-admit every orphan on a survivor, FIFO. Greedy decode is
        deterministic, so prefilling ``prompt + out[:-1]`` reproduces
        ``out[-1]`` and the stream continues exactly — no token loss, no
        duplicates. Orphans with no UP survivor are finished FAILED."""
        while self._pending:
            gid = self._pending[0]
            v = self.vslots[gid]
            if v.state is not _VState.PENDING:   # cancelled meanwhile
                self._pending.popleft()
                continue
            if not any(r.up for r in self.replicas):
                # nobody left to absorb it: FAILED, exactly-once
                self._pending.popleft()
                v.state = _VState.FAILED
                self._failed.append(gid)
                self.rstats["failed"] += 1
                continue
            if not self._candidates():
                break            # survivors busy; retry after a retire
            k = len(v.out)
            if k == 0:                            # died during prefill
                cont = v.req
            else:
                toks = np.concatenate([
                    np.asarray(v.req.tokens, np.int32),
                    np.asarray(v.out[:k - 1], np.int32)])
                cont = dataclasses.replace(v.req, tokens=toks,
                                           gen=v.req.gen - (k - 1))
            v.state = _VState.BOUND
            if self._bind(gid, cont):
                self._pending.popleft()
                # a mid-prefill orphan re-prefills its whole prompt here
                # (atomic admit), so it is no longer PREFILLING
                self._prefilling.discard(gid)
                self.rstats["redispatches"] += 1
            else:                # chosen survivors died mid-bind: loop
                v.state = _VState.PENDING

    def take_failed(self) -> List:
        """Drain requests that could not be re-dispatched (no surviving
        replica): returns ``[(virtual slot, partial tokens), ...]``
        exactly once per failure; the slots are freed. The front-end
        calls this each step and finishes the handles FAILED."""
        out = []
        for gid in self._failed:
            v = self.vslots[gid]
            out.append((gid, list(v.out)))
            self._release(gid)
        self._failed = []
        return out

    # -- retire / cancel ----------------------------------------------------

    def retire(self, slot: int) -> Completion:
        v = self.vslots[slot]
        assert v.state is _VState.BOUND and v.remaining == 0, \
            f"retire of virtual slot {slot} in {v.state}"
        self.replicas[v.replica].engine.retire(v.pslot)
        now = self._now() if self._t0 is not None else 0.0
        comp = Completion(
            rid=v.rid, tokens=np.asarray(v.out, np.int32),
            prompt_len=len(v.req.tokens), arrival=v.req.arrival,
            t_admit=v.t_admit, t_first=v.t_admit, t_done=now)
        self._release(slot)
        return comp

    def cancel(self, slot: int) -> List[int]:
        """Drop virtual slot ``slot`` mid-generation (deadline expiry /
        caller cancel) and return its partial tokens — works whether the
        request is live on a replica, orphaned awaiting re-dispatch, or
        already failed."""
        v = self.vslots[slot]
        if v.free:
            raise ValueError(f"cancel on free virtual slot {slot}")
        if v.state is _VState.BOUND:
            self.replicas[v.replica].engine.cancel(v.pslot)
        elif v.state is _VState.PENDING:
            # stale deque entries would under-report free_slots capacity
            self._pending.remove(slot)
        elif v.state is _VState.FAILED:
            self._failed.remove(slot)
        partial = list(v.out)
        self._release(slot)
        self.rstats["cancels"] += 1
        return partial

    def _release(self, gid: int):
        self._prefilling.discard(gid)
        self.vslots[gid] = _VSlot()

    # -- drain / health surface ---------------------------------------------

    def drain(self, i: int):
        """No new admissions (or re-dispatches) to replica ``i``; its
        in-flight requests run to completion. ``drained(i)`` turns True
        once the last one retires — the replica is then removable."""
        if self.replicas[i].state is ReplicaState.UP:
            self.replicas[i].state = ReplicaState.DRAINING
            self.rstats["drains"] += 1

    def drained(self, i: int) -> bool:
        r = self.replicas[i]
        return (r.state is ReplicaState.DRAINING
                and r.engine.active_count() == 0)

    @property
    def states(self) -> List[ReplicaState]:
        return [r.state for r in self.replicas]

    # -- reporting ----------------------------------------------------------

    @property
    def stats(self) -> collections.Counter:
        """Fleet-aggregated engine counters + router-level counters
        (``routed_admits``, ``redispatches``, ``replicas_down``,
        ``failed``, ``drains``, ``affinity_hits``, ``router_steps``)."""
        agg = collections.Counter()
        for r in self.replicas:
            agg.update(r.engine.stats)
        agg.update(self.rstats)
        return agg

    @property
    def cache_bytes(self) -> int:
        return sum(r.engine.cache_bytes for r in self.replicas)

    def prefix_stats(self) -> Optional[List[dict]]:
        return None if self._caches is None else \
            [c.stats() for c in self._caches]
