"""Slot-based continuous-batching serving engine.

Lifecycle (docs/serving.md):

  submit -> [queue] -> admit (bucketed prefill, write slot) -> decode ...
            -> retire (slot freed) -> refill mid-flight from the queue

Admission is non-atomic under the hood: ``begin_admit`` binds a request to
a slot (PREFILLING — occupied, but skipping decode lanes) and
``continue_admit`` consumes prompt tokens up to a budget, installing the
slot once the prompt is done. ``admit`` is the atomic composition; the
scheduling layer (serve/scheduler.py) time-slices ``continue_admit`` to
interleave chunked prefills with decode steps, so a long prompt never
stalls co-resident streams. Either way the computed tokens are identical.

One shared jitted decode step runs over all ``n_slots`` slots per iteration;
per-slot ``pos`` valid-lengths inside the cache drive the masked decode
attention (``kernels/flash_decode/decode_attention`` on TPU), so slots at
different sequence positions coexist in one step. Finished requests retire
and their slot is refilled immediately — no batch barrier, which is where
the throughput win over static batching comes from on ragged traces
(``benchmarks/bench_serve.py`` gates it).

Prompt-length bucketing bounds recompiles: prompts are right-padded to the
next bucket and prefilled with per-sample true ``lengths`` (causal attention
keeps cache rows < length exact — see ``lm_prefill``). Ragged prefill is
only sound for pure global-attention stacks; sliding-window archs fall back
to exact-length prefill (one compile per distinct length).

The engine dispatches on the config's **slot-cache contract**
(``serve/cache.py::cache_contract``, docs/serving.md) rather than
hard-coding KV: recurrent stacks (rwkv6, jamba hybrids) get a
``RecurrentSlotCache`` of fixed-size states — cold admits prefill the
longest chunk-quantized prefix exactly and walk the remainder through the
shared batch-1 decode step (bounded compiles without ragged soundness), and
retire *resets* the slot state instead of relying on the ``pos`` mask.

Pruned models plug in transparently: a ``cfg.pruned(...)`` config shrinks
``eff_qk`` and the slot cache's K rows shrink with it — the structured-
pruning serving payoff (smaller cache -> more slots per HBM byte).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import errors
from repro.serve.cache import RecurrentSlotCache, SlotCache, cache_contract


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (P,) int32 prompt tokens
    gen: int                      # tokens to generate (>= 1)
    arrival: float = 0.0          # seconds relative to trace start
    frames: Optional[np.ndarray] = None   # (S, D) enc-dec memory frames
    deadline: Optional[float] = None      # absolute trace time; None = none


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray            # (gen,) generated tokens
    prompt_len: int
    arrival: float
    t_admit: float                # queue -> slot (prefill done)
    t_first: float                # first generated token available
    t_done: float                 # last token available

    @property
    def latency(self) -> float:
        return self.t_done - self.arrival

    @property
    def ttft(self) -> float:
        return self.t_first - self.arrival


@dataclasses.dataclass
class _Prefill:
    """In-flight (possibly chunked) admit for one slot: the batch-1 local
    cache being built and how much of the prompt it has absorbed. Held
    aside until the whole prompt is consumed, then installed with a single
    slot scatter — the shared (possibly sharded) cache never sees a
    half-prefilled slot, so chunk writes stay shard-local for free."""
    req: Request
    local: object = None           # batch-1 cache pytree (None pre-chunk-1)
    consumed: int = 0              # prompt tokens absorbed into ``local``
    first: Optional[int] = None    # first generated token (set at the end)
    prefix_cache: object = None    # insert/lookup target (None if unused)


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    remaining: int = 0
    out: list = dataclasses.field(default_factory=list)
    req: Optional[Request] = None
    t_admit: float = 0.0
    t_first: float = 0.0
    pending: Optional[_Prefill] = None   # set while PREFILLING

    @property
    def free(self) -> bool:
        return self.req is None


def default_buckets(max_len: int, lo: int = 8):
    """Power-of-two prompt buckets up to max_len."""
    out, b = [], lo
    while b < max_len:
        out.append(b)
        b *= 2
    return out + [max_len]


class ServeEngine:
    """Continuous-batching engine over a preallocated ``SlotCache``.

    Parameters
    ----------
    model, params : the (possibly pruned) model to serve.
    n_slots       : concurrent requests sharing the decode step.
    max_len       : per-slot sequence budget (prompt + generation).
    buckets       : prompt-length buckets (default: powers of two).
    mem_len       : enc-dec only — fixed encoder-memory length every
                    request's ``frames`` must match (cross K/V is unmasked).
    sharding      : optional ``serve.sharding.ServeSharding`` — run the
                    shared decode step under pjit with params placed by
                    ``distrib.sharding.param_specs`` and every slot-cache
                    leaf model-sharded per ``slot_specs`` (the slot axis is
                    the data axis). Admit/retire/cancel semantics are
                    unchanged; prefill outputs are pinned to the batch-1
                    local specs so the slot write is a sharded scatter.
    """

    def __init__(self, model, params, *, n_slots: int, max_len: int,
                 buckets=None, mem_len: Optional[int] = None,
                 sharding=None):
        cfg = model.cfg
        if model.prefill is None or model.decode_step is None:
            raise ValueError(errors.msg("no_serving_path", name=cfg.name,
                                        family=cfg.family))
        # corp_prune returns host (numpy) leaves; indexing ops inside the
        # jitted prefill need device arrays
        self.model, self.cfg = model, cfg
        self.params = jax.tree.map(jnp.asarray, params)
        self.sharding = sharding
        if sharding is not None:
            from repro.distrib.sharding import param_specs, shardings_of
            self.params = jax.device_put(
                self.params, shardings_of(
                    param_specs(self.params, sharding.mesh), sharding.mesh))
        self.n_slots, self.max_len = n_slots, max_len
        self.mem_len = mem_len
        self.contract = cache_contract(cfg)
        # ragged (bucketed) prefill: sound iff every cache row < length is
        # independent of the padded tail — pure causal global attention
        self.ragged_ok = set(cfg.layer_kinds) == {"attn"}
        self.buckets = sorted(buckets) if buckets else \
            default_buckets(max_len)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.tokens = np.zeros((n_slots,), np.int32)   # next decode inputs
        cache_cls = RecurrentSlotCache if self.contract == "recurrent" \
            else SlotCache
        self.slotcache = cache_cls(self._cache_template, n_slots,
                                   sharding=sharding, name=cfg.name)
        # sharded: pin out_shardings so the decode/prefill caches keep the
        # slot-cache layout (tokens replicated — every host reads them)
        if sharding is None:
            tok_out = glob_out = local_out = None
        else:
            from jax.sharding import NamedSharding, PartitionSpec
            tok_out = NamedSharding(sharding.mesh, PartitionSpec())
            glob_out = (tok_out, self.slotcache._shardings)
            local_out = (tok_out, self.slotcache._local_shardings)
        self._decode = jax.jit(self._decode_impl, donate_argnums=(2,),
                               out_shardings=glob_out)
        # batch-1 decode over a *local* (pre-scatter) cache: the prefix-hit
        # suffix path. NOT donated — the input may be a shared PrefixCache
        # entry whose buffers must survive the call.
        self._decode1 = jax.jit(self._decode_impl, out_shardings=local_out)
        self._prefill = jax.jit(self._prefill_impl, out_shardings=local_out)
        self.stats = collections.Counter()
        self._t0 = None

    # -- jitted steps -------------------------------------------------------

    def _cache_template(self, batch: int):
        req = {"tokens": jax.ShapeDtypeStruct((batch, min(self.buckets)),
                                              jnp.int32)}
        if self.cfg.family == "encdec":
            if self.mem_len is None:
                raise ValueError(errors.msg("encdec_needs_mem_len"))
            req["frames"] = jax.ShapeDtypeStruct(
                (batch, self.mem_len, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        return jax.eval_shape(
            lambda b: self.model.prefill(self.params, b, self.max_len)[1],
            req)

    def _argmax(self, logits):
        return jnp.argmax(logits[:, -1, : self.cfg.vocab_size],
                          axis=-1).astype(jnp.int32)

    def _prefill_impl(self, params, batch, lengths):
        logits, cache = self.model.prefill(
            params, batch, self.max_len,
            lengths=lengths if self.ragged_ok else None)
        return self._argmax(logits), cache

    def _decode_impl(self, params, tok, cache):
        logits, cache = self.model.decode_step(params, tok, cache)
        return self._argmax(logits), cache

    # -- slot management ----------------------------------------------------

    def _bucket(self, n: int) -> int:
        if not self.ragged_ok:
            return n                       # exact-length prefill
        for b in self.buckets:
            if b >= n:
                return b
        raise ValueError(errors.msg("prompt_exceeds_bucket", n=n,
                                    bucket=self.buckets[-1]))

    def _stat_bucket(self, L: int) -> int:
        """Aggregation key for the ``prefill_b*`` stats counters: the
        smallest bucket covering ``L``. Exact-length fallback prefills
        (one compile per distinct length) used to key stats by the exact
        length, so a long ragged trace grew ``stats`` without bound;
        bucketing the *key* keeps the counter family bounded by the bucket
        table while the compiled shapes stay exact."""
        for b in self.buckets:
            if b >= L:
                return b
        return self.buckets[-1]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def active_count(self) -> int:
        return sum(not s.free for s in self.slots)

    def decoding_count(self) -> int:
        """Occupied slots actually in the decode phase. A PREFILLING slot
        (non-atomic admit in flight) is occupied but has no token to feed
        the shared decode step yet — it skips decode lanes until its
        prompt is consumed."""
        return sum((not s.free) and s.pending is None for s in self.slots)

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def begin(self, t0: Optional[float] = None):
        """Anchor the engine clock. ``run``/``warmup`` call this themselves;
        external drivers (the serving front-end) that call ``admit``/
        ``decode_step`` directly must call it once before serving."""
        self._t0 = time.perf_counter() if t0 is None else t0

    # prefix reuse needs a *replayable* contract: pure causal global
    # attention (cache rows are a pure function of the tokens at or before
    # them — any prefix is a rewind), or a recurrent state reused whole
    # (serve/prefix.py::usable_prefix_len). Enc-dec is excluded because the
    # encoder memory keys the cross attention, not the prompt tokens alone;
    # sliding-window ring buffers are neither rewindable nor snapshot-whole.
    def prefix_eligible(self) -> bool:
        return (self.ragged_ok and self.cfg.family == "lm") \
            or self.contract == "recurrent"

    def begin_admit(self, req: Request, slot: int, prefix_cache=None):
        """Bind ``req`` to ``slot`` without running any prefill work.

        First half of the non-atomic admit the scheduler
        (serve/scheduler.py) drives: validates the request, consults the
        prefix cache, and marks the slot PREFILLING — occupied (``free``
        is False) but skipping decode lanes until ``continue_admit``
        consumes the whole prompt. On a prefix hit the cached rows are
        adopted up-front: the KV contract rewinds the entry to the hit
        length (exact by causality, serve/prefix.py), the recurrent
        contract reuses the whole-prefix state snapshot as-is.
        """
        P = len(req.tokens)
        if P + req.gen > self.max_len:
            raise ValueError(errors.msg("request_exceeds_max_len",
                                        rid=req.rid, prompt=P, gen=req.gen,
                                        max_len=self.max_len))
        if self.cfg.family == "encdec":
            fr = np.asarray(req.frames)
            if fr.shape[0] != self.mem_len:
                raise ValueError(errors.msg(
                    "frames_mem_len_mismatch", rid=req.rid,
                    frames=fr.shape[0], mem_len=self.mem_len))
        use_prefix = prefix_cache is not None and self.prefix_eligible()
        recurrent = self.contract == "recurrent"
        st = _Prefill(req=req,
                      prefix_cache=prefix_cache if use_prefix else None)
        hit = prefix_cache.lookup(req.tokens, whole_entry=recurrent) \
            if use_prefix else None
        if hit is not None:
            entry, hit_len = hit
            if recurrent:
                st.local = entry.cache
            else:
                from repro.models.lm import override_cache_pos
                st.local = override_cache_pos(
                    entry.cache, jnp.full((1,), hit_len, jnp.int32))
            st.consumed = hit_len
            self.stats["prefix_hits"] += 1
            self.stats["prefix_reused_tokens"] += hit_len
            self.stats["prefix_suffix_tokens"] += P - hit_len
        s = self.slots[slot]
        if s.out:                      # slot previously served a request
            self.stats["refills"] += 1
        s.rid, s.req, s.out = req.rid, req, []
        s.remaining = req.gen
        s.pending = st
        self.stats["admits"] += 1

    def _first_chunk_len(self, n: int, P: int) -> int:
        """Prompt tokens the first prefill call of an admit consumes, given
        a budget of ``n`` (<= ``P``). Ragged stacks prefill any prefix
        (padded to a bucket); exact-length stacks quantize partial chunks
        to multiples of the smallest bucket so chunked serving cannot grow
        the compile count past the bucket table; recurrent stacks always
        leave >= 1 token for the batch-1 walk (matching the cold-admit
        path — their prefill never pads)."""
        if self.ragged_ok:
            return n
        if self.contract != "recurrent" and n >= P:
            return P                   # whole-prompt exact prefill
        cap = min(n, P - 1) if self.contract == "recurrent" else n
        lo = self.buckets[0]
        return max(1, lo * (cap // lo))

    def continue_admit(self, slot: int,
                       budget: Optional[int] = None) -> bool:
        """Consume up to ``budget`` prompt tokens of ``slot``'s in-flight
        admit (the whole remainder when None); True once the prompt is
        consumed and the slot is installed (first token on ``out``,
        decode-eligible).

        The chunk mechanics are pieces the engine already trusts: the
        first chunk is a bucketed/chunk-quantized *prefix prefill* — exact
        because every cache row carries only its own history (causal KV
        rows, swa ring slots keyed by absolute position, recurrent state)
        — and later chunks walk tokens one at a time through the batch-1
        decode step, identical to the prefix-splice suffix path. The local
        cache is installed with a single slot scatter at the end, so a
        half-prefilled slot never touches the shared (possibly sharded)
        cache.
        """
        s = self.slots[slot]
        st = s.pending
        if st is None:
            raise ValueError(errors.msg("continue_without_begin",
                                        slot=slot))
        req = st.req
        P = len(req.tokens)
        budget = P - st.consumed if budget is None else max(1, int(budget))
        nxt = None
        if st.local is None:           # first chunk: prefix prefill
            L0 = self._first_chunk_len(min(budget, P), P)
            if self.ragged_ok:
                toks = np.zeros((1, self._bucket(L0)), np.int32)
                toks[0, :L0] = req.tokens[:L0]
            else:
                toks = np.asarray(req.tokens[:L0], np.int32)[None]
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.family == "encdec":
                batch["frames"] = jnp.asarray(np.asarray(req.frames))[None]
            nxt, st.local = self._prefill(self.params, batch,
                                          jnp.asarray([L0], jnp.int32))
            self.stats[f"prefill_b{self._stat_bucket(L0)}"] += 1
            st.consumed = L0
            budget -= L0
            if st.prefix_cache is not None \
                    and self.contract == "recurrent" \
                    and L0 >= st.prefix_cache.min_hit:
                # chunk state inserted under its exact token prefix: the
                # whole-entry snapshot a later prompt extending it reuses
                from repro.serve.cache import cache_bytes
                st.prefix_cache.insert(req.tokens[:L0], st.local,
                                       cache_bytes(st.local))
        while budget > 0 and st.consumed < P:
            t = int(req.tokens[st.consumed])
            nxt, st.local = self._decode1(self.params,
                                          jnp.full((1, 1), t, jnp.int32),
                                          st.local)
            st.consumed += 1
            budget -= 1
        if st.consumed < P:
            self.stats["chunk_steps"] += 1
            return False
        st.first = int(nxt[0])
        self._install(slot, st)
        return True

    def _install(self, slot: int, st: _Prefill):
        """Prefill complete: insert into the prefix cache, scatter the
        local cache into the slot lane, and make the slot decode-eligible
        with its first generated token."""
        if st.prefix_cache is not None:
            from repro.serve.cache import cache_bytes
            st.prefix_cache.insert(st.req.tokens, st.local,
                                   cache_bytes(st.local))
        s = self.slots[slot]
        now = self._now()
        s.out = [st.first]
        s.remaining = st.req.gen - 1
        s.t_admit = s.t_first = now
        self.tokens[slot] = st.first
        self.slotcache.write_slot(st.local, slot)
        s.pending = None

    def admit(self, req: Request, slot: int, prefix_cache=None):
        """Prefill ``req`` and install it into ``slot`` — the atomic
        composition of ``begin_admit`` + ``continue_admit`` with an
        unbounded budget (byte-identical streams either way; chunking via
        the scheduler changes *when* the work happens, never *what* is
        computed).

        With a ``prefix_cache`` (serve/prefix.py) on a prefix-eligible
        config, a prompt sharing a cached prefix skips recomputing it; the
        full prefill result is inserted back into the cache either way.
        """
        self.begin_admit(req, slot, prefix_cache=prefix_cache)
        self.continue_admit(slot)

    def decode_step(self):
        """One shared decode step over every slot; returns retired slots."""
        nxt, cache = self._decode(self.params,
                                  jnp.asarray(self.tokens[:, None]),
                                  self.slotcache.cache)
        self.slotcache.cache = cache
        nxt = np.asarray(nxt)
        active = self.decoding_count()
        self.stats["decode_steps"] += 1
        self.stats["decode_lanes"] += active
        self.stats["max_concurrent"] = max(self.stats["max_concurrent"],
                                           active)
        retired = []
        for i, s in enumerate(self.slots):
            # PREFILLING slots skip decode lanes: their lane computed
            # garbage (stale token over a stale cache row, like a free
            # slot's) and the install scatter overwrites the row wholesale
            if s.free or s.pending is not None:
                continue
            s.out.append(int(nxt[i]))
            self.tokens[i] = nxt[i]
            s.remaining -= 1
            if s.remaining == 0:
                retired.append(i)
        return retired

    def retire(self, slot: int) -> Completion:
        """Free ``slot`` and return its finished request's Completion.
        The slot is immediately refillable (the next admit overwrites it)."""
        s = self.slots[slot]
        comp = Completion(
            rid=s.rid, tokens=np.asarray(s.out, np.int32),
            prompt_len=len(s.req.tokens), arrival=s.req.arrival,
            t_admit=s.t_admit, t_first=s.t_first, t_done=self._now())
        s.rid, s.req, s.remaining, s.pending = -1, None, 0, None
        if self.contract == "recurrent":
            self.slotcache.reset_slot(slot)
        return comp

    def cancel(self, slot: int) -> List[int]:
        """Retire hook for the front-end: drop ``slot``'s request mid-
        generation (deadline expiry / caller cancel) and return the partial
        tokens produced so far. The slot is refillable on the next admit,
        exactly like a normal retire — its stale cache lanes are inert
        (masked by ``pos``, or reset under the recurrent contract) until
        overwritten. Cancelling a PREFILLING slot discards the partial
        prefill outright (its local cache was never installed): zero
        tokens kept, slot immediately refillable."""
        s = self.slots[slot]
        if s.free:
            raise ValueError(errors.msg("cancel_free_slot", slot=slot))
        partial = list(s.out)
        s.rid, s.req, s.remaining, s.pending = -1, None, 0, None
        if self.contract == "recurrent":
            self.slotcache.reset_slot(slot)
        self.stats["cancels"] += 1
        return partial

    # -- driver -------------------------------------------------------------

    def run(self, requests: List[Request], *, log=None,
            prefill_chunk: Optional[int] = None) -> List[Completion]:
        """Serve a trace to completion; returns completions in rid order.

        ``prefill_chunk`` hands the interleaving to a scheduler with that
        per-iteration token budget (serve/scheduler.py): cold admits
        prefill at most that many prompt tokens per engine iteration, so
        occupied slots take a decode step between chunks. Streams are
        byte-identical either way.
        """
        from repro.serve.scheduler import Scheduler
        sched = Scheduler(self, prefill_chunk=prefill_chunk)
        queue = collections.deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        done: dict = {}
        self.begin()
        while queue or self.active_count():
            now = self._now()
            for slot in sched.advance():   # resume in-flight chunked admits
                if self.slots[slot].remaining == 0:
                    comp = self.retire(slot)
                    done[comp.rid] = comp  # gen==1: prefill token only
            free = self.free_slots()
            while queue and queue[0].arrival <= now and free:
                slot = free[0]
                started = sched.start(queue.popleft(), slot)
                if started and self.slots[slot].remaining == 0:
                    comp = self.retire(slot)
                    done[comp.rid] = comp  # gen==1: prefill token only
                else:
                    free.pop(0)
            if not sched.should_decode():
                if not self.active_count() and queue:
                    # idle until the next arrival
                    time.sleep(max(0.0, min(queue[0].arrival - self._now(),
                                            1e-3)))
                continue
            for slot in self.decode_step():
                s = self.slots[slot]
                if log:
                    log(f"[serve] rid={s.rid} done "
                        f"({len(s.out)} tok, slot {slot})")
                comp = self.retire(slot)
                done[comp.rid] = comp
        return [done[r.rid] for r in sorted(requests, key=lambda r: r.rid)]

    def warmup(self, prompt_lens=(8,), gen: int = 2, prefix: bool = False,
               prefill_chunk: Optional[int] = None):
        """Compile prefill (per bucket), decode, and the slot write outside
        any timed region; resets the engine afterwards. ``prefix=True``
        additionally compiles the batch-1 suffix decode the prefix-hit
        admit path uses; ``prefill_chunk`` warms the chunked-prefill path
        instead (the same batch-1 decode, plus the chunk-sized first-chunk
        prefill shapes, by running the warm trace through the scheduler)."""
        if prefix and not self.prefix_eligible():
            raise ValueError(errors.msg("prefix_ineligible",
                                        name=self.cfg.name))
        if prefix or prefill_chunk is not None:
            local = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 self._cache_template(1))
            # the splice path = pos rewind (KV contract only) + batch-1
            # suffix decode — the same walk chunked admits take; compile
            # both so the first prefix hit / chunk isn't charged compile
            # time
            if self.contract != "recurrent":
                from repro.models.lm import override_cache_pos
                local = override_cache_pos(local, jnp.zeros((1,), jnp.int32))
            self._decode1(self.params, jnp.zeros((1, 1), jnp.int32), local)
        reqs = []
        for i, b in enumerate(sorted({self._bucket(p)
                                      for p in prompt_lens})):
            # a bucket-sized prompt can overflow the per-slot budget
            # (b == max_len); shrink the prompt — it still rounds back up
            # to the same bucket, so the same prefill shape compiles
            p = max(1, min(b, self.max_len - gen))
            frames = None
            if self.cfg.family == "encdec":
                frames = np.zeros((self.mem_len, self.cfg.d_model),
                                  np.float32)
            reqs.append(Request(rid=-(i + 1),
                                tokens=np.zeros((p,), np.int32), gen=gen,
                                frames=frames))
        self.run(reqs, prefill_chunk=prefill_chunk)
        self.reset()

    def reset(self):
        self.slotcache.reset()
        self.tokens[:] = 0
        self.slots = [_Slot() for _ in range(self.n_slots)]
        self.stats = collections.Counter()

    @property
    def cache_bytes(self) -> int:
        return self.slotcache.bytes

    @property
    def device_cache_bytes(self) -> int:
        """Largest per-device slot-cache footprint (== ``cache_bytes``
        unsharded; ~1/model-axis of it under a ``sharding`` — the number
        benchmarks/bench_serve_sharded.py gates)."""
        return self.slotcache.device_bytes


# ---------------------------------------------------------------------------
# static fixed-batch baseline (the pre-engine serve loop, trace-shaped)
# ---------------------------------------------------------------------------

def run_static_trace(model, params, requests: List[Request], *,
                     n_slots: int, max_len: int,
                     buckets=None) -> List[Completion]:
    """Serve the trace in fixed batches of ``n_slots``: each batch pads every
    prompt to the longest and decodes until the *longest* generation in the
    batch finishes — the batch barrier continuous batching removes."""
    cfg = model.cfg
    if set(cfg.layer_kinds) != {"attn"}:
        raise ValueError(errors.msg("static_trace_ineligible"))
    buckets = sorted(buckets) if buckets else default_buckets(max_len)
    vocab = cfg.vocab_size

    @jax.jit
    def prefill(params, batch, lengths):
        logits, cache = model.prefill(params, batch, max_len,
                                      lengths=lengths)
        return jnp.argmax(logits[:, -1, :vocab], -1).astype(jnp.int32), cache

    @jax.jit
    def decode(params, tok, cache):
        logits, cache = model.decode_step(params, tok, cache)
        return jnp.argmax(logits[:, -1, :vocab], -1).astype(jnp.int32), cache

    order = sorted(requests, key=lambda r: (r.arrival, r.rid))
    groups = [order[i:i + n_slots] for i in range(0, len(order), n_slots)]

    def bucket_of(group):
        Lmax = max(len(r.tokens) for r in group)
        return next((b for b in buckets if b >= Lmax), Lmax)

    # compile-warm every prefill bucket this trace will use (and the decode
    # step) outside the timed region, matching the engine's warmup — the
    # measured gap must be the batch barrier, not compile time
    for L in sorted({bucket_of(g) for g in groups}):
        tok, cache = prefill(params, {"tokens": jnp.zeros((n_slots, L),
                                                          jnp.int32)},
                             jnp.ones((n_slots,), jnp.int32))
        decode(params, tok[:, None], cache)

    done = []
    t0 = time.perf_counter()
    for group in groups:
        while time.perf_counter() - t0 < max(r.arrival for r in group):
            time.sleep(1e-4)               # batch can't start early
        B = n_slots
        L = bucket_of(group)
        toks = np.zeros((B, L), np.int32)
        lens = np.ones((B,), np.int32)
        for j, r in enumerate(group):
            toks[j, :len(r.tokens)] = r.tokens
            lens[j] = len(r.tokens)
        first, cache = prefill(params, {"tokens": jnp.asarray(toks)},
                               jnp.asarray(lens))
        outs = [[int(t)] for t in np.asarray(first)[:len(group)]]
        tok = first
        for _ in range(max(r.gen for r in group) - 1):
            tok, cache = decode(params, tok[:, None], cache)
            for j in range(len(group)):
                outs[j].append(int(tok[j]))
        t_done = time.perf_counter() - t0
        for j, r in enumerate(group):      # everyone waits for the batch
            done.append(Completion(
                rid=r.rid, tokens=np.asarray(outs[j][:r.gen], np.int32),
                prompt_len=len(r.tokens), arrival=r.arrival,
                t_admit=t_done, t_first=t_done, t_done=t_done))
    return sorted(done, key=lambda c: c.rid)


# ---------------------------------------------------------------------------
# synthetic ragged traces + reporting
# ---------------------------------------------------------------------------

def _substream(seed: int, salt: int) -> np.random.RandomState:
    """Independent RNG stream per trace field (seed determinism contract)."""
    return np.random.RandomState((seed * 0x9E3779B1 + salt) & 0xFFFFFFFF)


def synthetic_trace(n: int, vocab: int, *, seed: int = 0,
                    prompt_range=(8, 48), gen_range=(4, 48),
                    rate: Optional[float] = None,
                    deadline_range=None, deadline_frac: float = 1.0,
                    prefix_len: int = 0, mem_len: Optional[int] = None,
                    d_model: int = 0) -> List[Request]:
    """Ragged arrival trace: mixed prompt/gen lengths, optional Poisson
    arrivals at ``rate`` req/s (default: all available at t=0).

    Every field draws from its own seed-derived substream, so ``seed``
    fully determinizes the trace field-by-field: toggling ``rate`` cannot
    reshuffle prompt/gen lengths, and adding deadlines cannot perturb the
    arrival timeline (previously one shared stream coupled every draw
    order — ``tests/test_serve_properties.py`` pins the contract).

    ``deadline_range=(lo, hi)`` gives a ``deadline_frac`` fraction of
    requests an absolute deadline ``arrival + U(lo, hi)`` seconds (the
    rest run un-deadlined — the "deadline mix"). ``prefix_len > 0``
    prepends one shared system prompt of that many tokens to every request
    (``prompt_range`` then sizes the per-request *suffix*) — the
    prefix-cache workload. ``mem_len`` (with ``d_model``) attaches
    per-request encoder-memory frames of that fixed length — the enc-dec
    workload (``ServeEngine(mem_len=...)``).
    """
    rng_arr = _substream(seed, 1)
    rng_len = _substream(seed, 2)
    rng_tok = _substream(seed, 3)
    rng_dl = _substream(seed, 4)
    rng_fr = _substream(seed, 5)
    arrivals = np.zeros(n) if rate is None else \
        np.cumsum(rng_arr.exponential(1.0 / rate, size=n))
    shared = rng_tok.randint(0, vocab, size=prefix_len).astype(np.int32) \
        if prefix_len else None
    reqs = []
    for i in range(n):
        P = int(rng_len.randint(prompt_range[0], prompt_range[1] + 1))
        G = int(rng_len.randint(gen_range[0], gen_range[1] + 1))
        toks = rng_tok.randint(0, vocab, size=P).astype(np.int32)
        if shared is not None:
            toks = np.concatenate([shared, toks])
        deadline = None
        if deadline_range is not None:
            budget = float(rng_dl.uniform(*deadline_range))
            if rng_dl.uniform() < deadline_frac:
                deadline = float(arrivals[i]) + budget
        frames = None
        if mem_len is not None:
            assert d_model > 0, "mem_len= needs d_model="
            frames = rng_fr.randn(mem_len, d_model).astype(np.float32)
        reqs.append(Request(rid=i, tokens=toks, gen=G,
                            arrival=float(arrivals[i]), deadline=deadline,
                            frames=frames))
    return reqs


def percentile_table(completions: List[Completion], wall: float) -> dict:
    """p50/p99 latency + aggregate throughput over a served trace."""
    lat = np.asarray([c.latency for c in completions])
    ttft = np.asarray([c.ttft for c in completions])
    total = int(sum(len(c.tokens) for c in completions))
    return {
        "requests": len(completions),
        "tokens": total,
        "wall_s": wall,
        "tok_per_s": total / max(wall, 1e-9),
        "lat_p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "lat_p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "ttft_p50_ms": float(np.percentile(ttft, 50)) * 1e3,
        "ttft_p99_ms": float(np.percentile(ttft, 99)) * 1e3,
    }


def format_table(rows: List[dict], keys=None) -> str:
    """Markdown table from a list of same-keyed dicts."""
    keys = keys or list(rows[0])
    def fmt(v):
        return f"{v:.1f}" if isinstance(v, float) else str(v)
    out = ["| " + " | ".join(keys) + " |",
           "|" + "---|" * len(keys)]
    for r in rows:
        out.append("| " + " | ".join(fmt(r.get(k, "-")) for k in keys)
                   + " |")
    return "\n".join(out)
