"""Continuous-batching serving subsystem (slot engine + async front-end).

See docs/serving.md for the slot lifecycle, slot-cache contracts, and the
front-end's queue/deadline/prefix-cache semantics.
"""
from repro.serve.cache import (RecurrentSlotCache, SlotCache, cache_bytes,
                               cache_contract)
from repro.serve.engine import (Completion, Request, ServeEngine,
                                run_static_trace, synthetic_trace,
                                percentile_table)
from repro.serve.errors import ERRORS
from repro.serve.frontend import (AsyncServeFrontend, Handle, ServeFrontend,
                                  frontend_table)
from repro.serve.prefix import PrefixCache
from repro.serve.queue import Overloaded, Status
from repro.serve.router import ReplicaRouter, ReplicaState
from repro.serve.scheduler import AdmissionQueue, Scheduler
from repro.serve.sharding import (ServeSharding, device_bytes_estimate,
                                  slot_specs)

__all__ = ["SlotCache", "RecurrentSlotCache", "cache_bytes",
           "cache_contract", "ERRORS", "Request", "Completion",
           "ServeEngine", "run_static_trace", "synthetic_trace",
           "percentile_table", "ServeFrontend", "AsyncServeFrontend",
           "Handle", "frontend_table", "PrefixCache", "AdmissionQueue",
           "Scheduler", "Overloaded", "Status", "ReplicaRouter",
           "ReplicaState", "ServeSharding", "slot_specs",
           "device_bytes_estimate"]
