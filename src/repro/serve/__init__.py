"""Continuous-batching serving subsystem (slot-based engine + KV cache).

See docs/serving.md for the slot lifecycle and cache layout.
"""
from repro.serve.cache import SlotCache, cache_bytes
from repro.serve.engine import (Completion, Request, ServeEngine,
                                run_static_trace, synthetic_trace,
                                percentile_table)

__all__ = ["SlotCache", "cache_bytes", "Request", "Completion",
           "ServeEngine", "run_static_trace", "synthetic_trace",
           "percentile_table"]
