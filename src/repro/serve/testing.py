"""Pure-Python fake engines for scheduler/router tests and CPU benches.

``FleetFakeEngine`` exposes exactly the engine-agnostic slot surface the
front-end and ``ReplicaRouter`` consume (``free_slots`` / ``admit`` /
``decode_step`` / ``retire`` / ``cancel`` / ``begin`` / ``slots`` /
``active_count``), plus the non-atomic ``begin_admit`` /
``continue_admit`` / ``decoding_count`` split that the scheduler's
chunked-prefill policy drives, with no jax anywhere, so fleet-level
scheduling paths run instantly and deterministically on CI. Like the
real engine, a mid-prefill slot holds its work aside and "installs"
atomically when the prompt is consumed — the recurrent fake only
scatters its state vector at install, so the property suites can check
that chunk writes never leak into the shared state before completion.

Two properties matter for fleet tests:

- **attributable tokens** — ``fleet_token(rid, i)`` is injective in
  ``(rid, i)``, so any cross-replica or cross-request contamination is
  detectable by value. Prompts in tests must stay below
  ``FLEET_TOKEN_BASE`` so prompt tokens can never collide with generated
  ones.
- **greedy determinism, mimicked** — a real engine re-prefilled with
  ``prompt + out[:-1]`` reproduces ``out[-1]`` exactly (argmax of the
  same logits). The fake mimics that: when a prompt *ends with* one of
  the rid's own generated tokens, the "prefill" continues the stream
  from it instead of restarting at index 0. That is precisely the
  router's re-dispatch contract, so replica-death tests exercise the
  real overlap bookkeeping.

Fault injection: set ``fail_next_admit = True`` to make the next admit
raise (death during prefill), ``fail_next_decode = True`` for death
mid-decode. ``step_time`` adds a per-``decode_step`` sleep (the whole
fused step, lanes in parallel) so FakeEngine-backed throughput benches
model a fleet of fixed-cost decode steps.
"""
from __future__ import annotations

import time
from typing import List, Optional

from repro.serve import errors

FLEET_TOKEN_BASE = 10_000


def fleet_token(rid: int, i: int) -> int:
    """The i-th token a FleetFakeEngine generates for request ``rid``.
    Injective in (rid, i); always >= FLEET_TOKEN_BASE."""
    return (rid + 1) * FLEET_TOKEN_BASE + i


class _FakeSlot:
    def __init__(self):
        self.rid, self.remaining, self.out, self.req = -1, 0, [], None
        self._next = 0                     # next stream index to emit
        self.pending = None                # prompt tokens left to prefill

    @property
    def free(self):
        return self.req is None


class _FakeCompletion:
    def __init__(self, rid, tokens):
        self.rid, self.tokens = rid, tokens


class _FakeCfg:
    name, family = "fleet-fake", "lm"
    vocab_size = 1 << 30


class FleetFakeEngine:
    """Engine-surface fake: one ``decode_step`` = one token per active
    slot, ``step_time`` seconds of (GIL-releasing) wall time per step."""

    cfg = _FakeCfg()
    contract = "kv"                # slot-cache contract (docs/serving.md)

    def __init__(self, n_slots: int, *, step_time: float = 0.0,
                 prefix_ok: bool = False):
        self.n_slots = n_slots
        self.step_time = step_time
        self._prefix_ok = prefix_ok
        self.slots = [_FakeSlot() for _ in range(n_slots)]
        self.stats = {"admits": 0, "decode_steps": 0, "cancels": 0,
                      "chunk_steps": 0}
        self.fail_next_admit = False
        self.fail_next_decode = False
        self.cache_bytes = 0

    def begin(self, t0: Optional[float] = None):
        self._t0 = t0

    def prefix_eligible(self) -> bool:
        return self._prefix_ok

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def active_count(self) -> int:
        return sum(not s.free for s in self.slots)

    def _start_index(self, req) -> int:
        """Greedy-determinism mimicry: a prompt ending in one of rid's
        own generated tokens (index ``i``) is a re-dispatch
        continuation, so the 'prefill' emits stream token ``i + 1`` —
        exactly what a real engine's argmax reproduces when re-prefilled
        with ``prompt + out[:-1]``. Fresh prompts start at index 0."""
        t = int(req.tokens[-1])
        if t >= FLEET_TOKEN_BASE:
            rid, i = divmod(t, FLEET_TOKEN_BASE)
            if rid - 1 == req.rid:
                return i + 1
        return 0

    def decoding_count(self) -> int:
        """Occupied slots past their prefill (eligible for decode lanes);
        a PREFILLING slot is active but not decoding."""
        return sum((not s.free) and s.pending is None for s in self.slots)

    def begin_admit(self, req, slot: int, prefix_cache=None):
        """First half of the non-atomic admit: bind the slot, no prefill
        work yet. The slot is PREFILLING (skipped by decode) until
        ``continue_admit`` consumes the whole prompt."""
        if self.fail_next_admit:
            self.fail_next_admit = False
            raise RuntimeError("injected admit failure")
        s = self.slots[slot]
        assert s.free, f"admit into occupied slot {slot}"
        self.stats["admits"] += 1
        s.rid, s.req = req.rid, req
        s.out = []
        s._next = self._start_index(req)
        s.remaining = req.gen
        s.pending = len(req.tokens)

    def continue_admit(self, slot: int,
                       budget: Optional[int] = None) -> bool:
        """Consume up to ``budget`` prompt tokens (the whole remainder
        when None); True once the prompt is consumed and the first
        token is installed."""
        s = self.slots[slot]
        if s.pending is None:
            raise ValueError(errors.msg("continue_without_begin",
                                        slot=slot))
        take = s.pending if budget is None \
            else min(max(1, int(budget)), s.pending)
        s.pending -= take
        if s.pending:
            self.stats["chunk_steps"] += 1
            return False
        self._install(slot)
        return True

    def _install(self, slot: int):
        """Prompt fully consumed: emit the prefill token. The recurrent
        subclass also scatters its state vector here — held aside until
        completion, exactly like the real engine's slot-cache write."""
        s = self.slots[slot]
        s.out = [fleet_token(s.rid, s._next)]
        s._next += 1
        s.remaining = s.req.gen - 1
        s.pending = None

    def admit(self, req, slot: int, prefix_cache=None):
        """Atomic admit: ``begin_admit`` + ``continue_admit`` over the
        whole prompt in one call."""
        self.begin_admit(req, slot, prefix_cache=prefix_cache)
        self.continue_admit(slot)

    def decode_step(self) -> List[int]:
        if self.fail_next_decode:
            self.fail_next_decode = False
            raise RuntimeError("injected decode failure")
        if self.step_time:
            time.sleep(self.step_time)             # releases the GIL
        self.stats["decode_steps"] += 1
        retired = []
        for i, s in enumerate(self.slots):
            if s.free or s.pending is not None or s.remaining == 0:
                continue
            s.out.append(fleet_token(s.rid, s._next))
            s._next += 1
            s.remaining -= 1
            if s.remaining == 0:
                retired.append(i)
        return retired

    def retire(self, slot: int) -> _FakeCompletion:
        s = self.slots[slot]
        assert not s.free, f"retire of free slot {slot}"
        comp = _FakeCompletion(s.rid, list(s.out))
        s.rid, s.req, s.remaining, s.pending = -1, None, 0, None
        return comp

    def cancel(self, slot: int) -> List[int]:
        s = self.slots[slot]
        if s.free:
            raise ValueError(f"cancel of free slot {slot}")
        partial = list(s.out)
        s.rid, s.req, s.remaining, s.pending = -1, None, 0, None
        self.stats["cancels"] += 1
        return partial


FAKE_STATE_SIZE = 4                # fixed per-slot state width (recurrent)


class RecurrentFleetFakeEngine(FleetFakeEngine):
    """``FleetFakeEngine`` honouring the *recurrent* slot-cache contract
    (docs/serving.md "Slot-cache contracts"): per-slot state is a
    fixed-size vector written wholesale at admit (the state scatter),
    advanced by ONE shared recurrent step per ``decode_step``, and zeroed
    at retire/cancel — never grown. The state encodes
    ``(rid + 1, tokens processed)`` injectively, so ``check_state`` can
    detect by value every contract violation the property suites hunt:
    state growth, a missed reset (stale state visible to the next admit),
    and cross-slot/cross-replica contamination."""

    contract = "recurrent"

    def __init__(self, n_slots: int, **kw):
        super().__init__(n_slots, **kw)
        self.state = [self._zero() for _ in range(n_slots)]

    @staticmethod
    def _zero():
        return [0] * FAKE_STATE_SIZE

    def begin_admit(self, req, slot: int, prefix_cache=None):
        assert self.state[slot] == self._zero(), \
            f"admit into slot {slot} over stale recurrent state"
        super().begin_admit(req, slot, prefix_cache=prefix_cache)

    def _install(self, slot: int):
        super()._install(slot)
        s = self.slots[slot]
        # scatter: the whole prompt + the prefill token, written at once
        # when the (possibly chunked) prefill completes — never earlier
        self.state[slot] = [s.rid + 1, len(s.req.tokens) + 1] \
            + [0] * (FAKE_STATE_SIZE - 2)

    def decode_step(self) -> List[int]:
        stepped = [i for i, s in enumerate(self.slots)
                   if not s.free and s.pending is None and s.remaining > 0]
        retired = super().decode_step()
        for i in stepped:                  # the one shared recurrent step
            self.state[i][1] += 1
        return retired

    def retire(self, slot: int):
        comp = super().retire(slot)
        self.state[slot] = self._zero()    # contract: reset, not dangle
        return comp

    def cancel(self, slot: int) -> List[int]:
        partial = super().cancel(slot)
        self.state[slot] = self._zero()
        return partial

    def check_state(self):
        """Assert the recurrent contract on the spot: constant state
        size, zeroed state on every free slot, and each occupied slot's
        state attributing exactly its own request at exactly its own
        position (prompt + emitted tokens)."""
        assert len(self.state) == self.n_slots
        for i, (s, st) in enumerate(zip(self.slots, self.state)):
            assert len(st) == FAKE_STATE_SIZE, \
                f"slot {i}: state grew to {len(st)}"
            if s.free:
                assert st == self._zero(), f"slot {i}: stale state {st}"
            elif s.pending is not None:
                # mid-chunked-prefill: work is held aside, nothing may
                # touch the shared state until install
                assert st == self._zero(), \
                    f"slot {i}: state scattered before install: {st}"
            else:
                want = [s.rid + 1, len(s.req.tokens) + len(s.out)] \
                    + [0] * (FAKE_STATE_SIZE - 2)
                assert st == want, \
                    f"slot {i}: state {st} != expected {want}"


class ManualClock:
    """Injectable front-end clock for deterministic deadline tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt
