"""Scheduling layer: the admit/prefill/decode interleaving policy.

Scheduling used to be smeared across the serving stack — the engine's
``admit`` ran a whole-prompt prefill inline, the front-end driver and the
replica router each hard-coded when to admit versus decode, and the
admission-queue policies lived in their own module. This layer owns all of
it behind the existing engine-agnostic slot surface:

    caller (frontend.py driver / engine.run / router stepping)
        |
        v
    Scheduler -- owns *when* admission work happens
        |   start(req, slot)  : begin_admit + first chunk
        |   advance()         : one chunk per PREFILLING slot
        |   AdmissionQueue    : who waits, and in what order
        v
    engine slot surface -- owns *what* is computed
        begin_admit / continue_admit / decode_step / retire / cancel

The first policy is **chunked prefill**: a cold admit consumes at most
``prefill_chunk`` prompt tokens per engine iteration. A slot mid-prefill is
occupied but PREFILLING — it skips decode lanes (``decoding_count``) until
its prompt is consumed, so co-resident slots take a decode step between
chunks and a long prompt never freezes their streams. Chunking changes
*when* work happens, never *what* is computed: token streams are
byte-identical to the unchunked engine on every slot-cache contract
(docs/serving.md "Scheduler" carries the per-contract exactness argument;
``benchmarks/bench_serve.py`` gates both the identity and the co-resident
decode-gap p99 win).

``prefill_chunk=None`` (the default) is the atomic policy: ``start`` runs
the engine's one-shot ``admit`` to completion, byte-for-byte the pre-PR-10
behavior.

Pure Python, no jax — like the queue policies it absorbed, this module is
scheduling state the property suite (``tests/test_serve_properties.py``)
drives against a slot-state oracle.
"""
from __future__ import annotations

from typing import List, Optional

from repro.serve import errors


class AdmissionQueue:
    """Bounded waiting room between ``submit`` and a free engine slot.

    Items must expose ``prompt_len`` and ``deadline`` attributes (the
    front-end queues its request handles). ``push`` refuses items beyond
    ``depth`` — the caller turns that into an ``Overloaded`` result
    (serve/queue.py). Deadlines are enforced here too: ``take_expired``
    drops waiting items whose deadline passed without ever touching the
    engine.

    ``policy``:
      - ``"fifo"`` — strict arrival order.
      - ``"spf"`` — shortest-prompt-first: ``pop`` picks the waiting item
        with the fewest prompt tokens (ties broken by arrival order, so
        equal-length requests stay FIFO).
    """

    POLICIES = ("fifo", "spf")

    def __init__(self, depth: int, policy: str = "fifo"):
        if depth < 0:
            raise ValueError(f"queue depth must be >= 0, got {depth}")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown queue policy {policy!r}; "
                             f"known: {self.POLICIES}")
        self.depth, self.policy = depth, policy
        self._items: List = []

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    @property
    def full(self) -> bool:
        return len(self._items) >= self.depth

    def push(self, item) -> bool:
        """Enqueue ``item``; False (and no side effect) when full."""
        if self.full:
            return False
        self._items.append(item)
        return True

    def pop(self):
        """Next item to admit under the configured policy."""
        if not self._items:
            raise IndexError("pop from empty AdmissionQueue")
        if self.policy == "spf":
            i = min(range(len(self._items)),
                    key=lambda j: self._items[j].prompt_len)
        else:
            i = 0
        return self._items.pop(i)

    def take_expired(self, now: float) -> List:
        """Remove and return every waiting item whose deadline has passed
        (``deadline <= now``); queue order of the survivors is preserved."""
        expired = [it for it in self._items
                   if it.deadline is not None and it.deadline <= now]
        if expired:
            self._items = [it for it in self._items
                           if not (it.deadline is not None
                                   and it.deadline <= now)]
        return expired

    def remove(self, item) -> bool:
        """Remove a specific waiting item (explicit cancel); False if the
        item is not queued."""
        try:
            self._items.remove(item)
            return True
        except ValueError:
            return False


class Scheduler:
    """Admit/prefill/decode interleaving policy over one engine.

    Parameters
    ----------
    engine        : anything exposing the slot surface. The atomic policy
                    needs only ``admit``; chunking additionally needs the
                    non-atomic ``begin_admit``/``continue_admit`` split
                    (refused up-front via ``errors.py`` otherwise).
    prefill_chunk : max prompt tokens one admit consumes per engine
                    iteration; None = atomic (whole-prompt) admits.
    queue_depth   : bounded waiting room (0 = admit-or-reject).
    policy        : admission order, ``AdmissionQueue.POLICIES``.
    prefix_cache  : optional ``PrefixCache`` handed to every admit.

    Drivers call ``start`` for a fresh admission, ``advance`` once per
    iteration to push every PREFILLING slot one chunk forward, and
    ``should_decode`` to decide whether a shared decode step has any lane
    to serve. ``release`` forgets a PREFILLING slot freed behind the
    scheduler's back (deadline expiry, caller cancel, replica failure) —
    the partial prefill is discarded with it, zero tokens kept.
    """

    def __init__(self, engine, *, prefill_chunk: Optional[int] = None,
                 queue_depth: int = 0, policy: str = "fifo",
                 prefix_cache=None):
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(errors.msg("chunk_invalid",
                                            chunk=prefill_chunk))
            if not (hasattr(engine, "begin_admit")
                    and hasattr(engine, "continue_admit")):
                name = getattr(getattr(engine, "cfg", None), "name",
                               type(engine).__name__)
                raise ValueError(errors.msg("chunk_unsupported", name=name))
        self.engine = engine
        self.prefill_chunk = prefill_chunk
        self.prefix_cache = prefix_cache
        self.queue = AdmissionQueue(queue_depth, policy=policy)
        self._prefilling: set = set()

    @property
    def chunked(self) -> bool:
        return self.prefill_chunk is not None

    def prefilling(self) -> List[int]:
        """Slots whose admit is in flight (occupied, not yet decoding)."""
        return sorted(self._prefilling)

    def start(self, req, slot: int) -> bool:
        """Admit ``req`` into ``slot``; True once its prefill is complete
        (the first token exists on the slot). False marks the slot
        PREFILLING: later ``advance`` calls consume the rest of the
        prompt, one chunk per call."""
        if not self.chunked:
            self.engine.admit(req, slot, prefix_cache=self.prefix_cache)
            return True
        self.engine.begin_admit(req, slot, prefix_cache=self.prefix_cache)
        if self.engine.continue_admit(slot, self.prefill_chunk):
            return True
        self._prefilling.add(slot)
        return False

    def advance(self) -> List[int]:
        """One chunk of prefill for every PREFILLING slot; returns the
        slots whose prompt is now fully consumed (decode-eligible, first
        token on the slot). Call once per engine iteration — the per-slot
        budget discipline (at most ``prefill_chunk`` tokens per iteration)
        is exactly one ``continue_admit`` per slot per call."""
        done = []
        for slot in sorted(self._prefilling):
            if self.engine.continue_admit(slot, self.prefill_chunk):
                done.append(slot)
        self._prefilling.difference_update(done)
        return done

    def release(self, slot: int):
        """Forget a PREFILLING slot whose request left the engine
        (cancelled/expired/failed); no-op for non-prefilling slots."""
        self._prefilling.discard(slot)

    def should_decode(self) -> bool:
        """Whether a shared decode step has any lane to serve: occupied
        slots that are *not* mid-prefill. Engines without a
        ``decoding_count`` surface never hold a PREFILLING slot (the
        atomic policy is all they support), so occupancy is the answer."""
        dc = getattr(self.engine, "decoding_count", None)
        return (dc() if dc is not None else self.engine.active_count()) > 0
