"""Mesh sharding for the serving slot cache.

The serving half of the `distrib.sharding` story (docs/serving.md
"Mesh-sharded serving"): the engine's shared decode step runs under pjit
on a ``(data, model)`` mesh with every slot-cache leaf explicitly placed —
the structurally-inferred slot axis (``cache._infer_batch_axes``) becomes
the data axis, and each *payload* leaf shards over the model axis on the
dim its ``cache_contract`` family parallelises:

  contract    leaf                  model-sharded dim
  ---------   -------------------   ------------------------------
  kv          k / v                 kv heads        (..., S, Hkv, d)
  kv (MLA)    ckv / k_rope          latent rank     (..., T, r)
  recurrent   wkv                   rwkv heads      (..., H, N, N)
  recurrent   ssm                   ssm channels    (..., d_inner, N)
  recurrent   conv / shift          conv channels   (..., K, d_inner)
  encdec      k_mem / v_mem         cross heads     (..., M, H, d)
  (all)       pos / abs_pos         replicated bookkeeping

Dims are counted FROM THE END of the shape, so leading stack axes
(scanned segments prepend ``(reps, ...)``, enc-dec decoders prepend
``(n_layers, ...)``) shift nothing. A payload dim that does not divide
the model-axis size is never padded: the whole config is refused with
the shared ``shard_ineligible`` message (``serve/errors.py``), which is
exactly the eligibility matrix ``tests/test_serve_zoo.py`` pins — GQA
configs whose reduced form collapses to one kv head cannot model-shard.

Like ``distrib.sharding.stats_specs``, ``slot_specs`` accepts a plain
``{axis: size}`` dict in place of a mesh so the placement rules are
testable without devices.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.serve import errors

# Bookkeeping leaves that never shard over the model axis: per-slot valid
# lengths / ring positions are O(1) per slot and every device's decode
# mask consumes the whole vector.
REPLICATED_SLOT_LEAVES = frozenset({"pos", "abs_pos"})

# Payload leaves: the model-axis dim, counted from the end of the shape.
MODEL_DIM_FROM_END = {
    "k": 2, "v": 2,            # attn KV rows   (..., S, Hkv, d)
    "k_mem": 2, "v_mem": 2,    # enc-dec cross  (..., M, H, d)
    "wkv": 3,                  # rwkv6 state    (..., H, N, N)
    "ssm": 2,                  # mamba state    (..., d_inner, N)
    "conv": 1,                 # mamba conv     (..., K, d_inner)
    "shift": 1,                # rwkv shifts    (..., D)
    "ckv": 1,                  # MLA latent     (..., T, rank)
    "k_rope": 1,               # MLA rope keys  (..., T, r_rope)
}


class ServeSharding(NamedTuple):
    """How a serving engine is laid out on a mesh (the serve-side analogue
    of ``distrib.sharding.CalibSharding``).

    mesh: the device mesh the shared decode step runs under.
    data_axis: mesh axis the slot (batch) dim shards over.
    model_axis: mesh axis the cache payload dims shard over.
    """
    mesh: Mesh
    data_axis: str = "data"
    model_axis: str = "model"

    @property
    def sizes(self) -> dict:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    @property
    def data_size(self) -> int:
        return self.sizes.get(self.data_axis, 1)

    @property
    def model_size(self) -> int:
        return self.sizes.get(self.model_axis, 1)


def _mesh_sizes(mesh) -> dict:
    return mesh if isinstance(mesh, dict) else \
        dict(zip(mesh.axis_names, mesh.devices.shape))


def _leaf_name(kp) -> str:
    return str(getattr(kp[-1], "key", getattr(kp[-1], "idx", kp[-1])))


def slot_specs(template, batch_axes, mesh, *, data_axis: str = "data",
               model_axis: str = "model", name: str = "slot-cache"):
    """PartitionSpecs for a slot-cache pytree.

    Args:
      template: cache pytree (arrays or ``jax.eval_shape`` structs; only
        ``.shape``/``.ndim`` are inspected). Leaf *names* (the innermost
        dict key) choose the rule — see ``MODEL_DIM_FROM_END`` /
        ``REPLICATED_SLOT_LEAVES``; unknown leaves stay model-replicated.
      batch_axes: per-leaf slot-axis index pytree
        (``SlotCache.batch_axes``). The slot dim shards over ``data_axis``
        when it divides that axis size (a batch-1 local template therefore
        comes out data-replicated, which is what the scatter-admit needs).
      mesh: a ``jax.sharding.Mesh`` — or a plain ``{axis: size}`` dict,
        which makes the rules testable without devices.
      name: config name for the ``shard_ineligible`` refusal.

    Raises:
      ValueError(``errors.msg("shard_ineligible", ...)``) when any payload
      leaf's model dim does not divide the model-axis size — sharding is
      all-or-nothing per config, never padded.

    >>> tmpl = {"k": np.zeros((4, 16, 2, 8)), "v": np.zeros((4, 16, 2, 8)),
    ...         "pos": np.zeros((4,), np.int32)}
    >>> axes = {"k": 0, "v": 0, "pos": 0}
    >>> sp = slot_specs(tmpl, axes, {"data": 2, "model": 2})
    >>> sp["k"] == P("data", None, "model", None)
    True
    >>> sp["pos"] == P("data")        # bookkeeping: slot axis only
    True
    >>> local = slot_specs({"k": np.zeros((1, 16, 2, 8))}, {"k": 0},
    ...                    {"data": 2, "model": 2})
    >>> local["k"] == P(None, None, "model", None)   # batch-1: no data dim
    True
    >>> try:                          # Hkv=2 cannot split a 4-way axis
    ...     slot_specs(tmpl, axes, {"model": 4})
    ... except ValueError:
    ...     print("refused")
    refused
    """
    sizes = _mesh_sizes(mesh)
    d = sizes.get(data_axis, 1)
    m = sizes.get(model_axis, 1)

    flat = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    axes_flat = jax.tree_util.tree_leaves(batch_axes)
    specs = []
    for (kp, leaf), slot_ax in zip(flat, axes_flat):
        leaf_name = _leaf_name(kp)
        spec = [None] * leaf.ndim
        if d > 1 and leaf.shape[slot_ax] % d == 0:
            spec[slot_ax] = data_axis
        if m > 1 and leaf_name in MODEL_DIM_FROM_END:
            md = leaf.ndim - MODEL_DIM_FROM_END[leaf_name]
            if md < 0 or md == slot_ax or leaf.shape[md] % m:
                raise ValueError(errors.msg("shard_ineligible", name=name,
                                            leaf=leaf_name, m=m))
            spec[md] = model_axis
        specs.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def device_bytes_estimate(template, specs, mesh) -> int:
    """Analytic per-device bytes of a sharded cache (no allocation).

    Divides every leaf's total bytes by the product of the mesh-axis sizes
    its spec shards over — exact when every sharded dim divides (which
    ``slot_specs`` guarantees). Works on ``jax.eval_shape`` templates, so
    a full-scale (671B-class) config's footprint is computable on a laptop.

    >>> tmpl = {"k": np.zeros((4, 16, 8, 8), np.float32)}
    >>> sp = {"k": P("data", None, "model", None)}
    >>> device_bytes_estimate(tmpl, sp, {"data": 2, "model": 4})
    2048
    """
    sizes = _mesh_sizes(mesh)
    leaves = jax.tree_util.tree_leaves(template)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, P))
    total = 0
    for leaf, spec in zip(leaves, spec_leaves):
        nbytes = int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        denom = 1
        for names in spec:
            if names is None:
                continue
            group = names if isinstance(names, tuple) else (names,)
            denom *= int(np.prod([sizes.get(a, 1) for a in group]))
        total += nbytes // denom
    return int(total)
