"""Fault tolerance runtime (DESIGN.md §2.3).

Three mechanisms, matched to the failure modes of a 1000+-node pruning or
training job:

1. restart loop — ``run_with_restarts`` re-enters the step function from the
   newest valid checkpoint after any failure (atomicity is guaranteed by
   repro.checkpoint; data is deterministic-by-index so the restored cursor
   reproduces the exact stream).

2. bounded-staleness calibration — CORP's statistics are *means* over
   independent samples, so a host that dies mid-pass simply contributes
   fewer samples: ``TolerantAccumulator`` drops failed batches and
   re-weights by the surviving count n. This graceful-degradation property
   is unique to one-shot closed-form compression (an optimizer-based method
   would diverge); the paper's Table 3 shows accuracy is stable down to
   100 calibration samples, which bounds the damage of losing hosts.
   (The fused ``repro.core.calibrate.CalibrationEngine`` exposes the same
   behaviour through its ``fail_hook`` argument.)

2b. resumable calibration — the engine's accumulator is a plain pytree of
   linear sums, so any stream prefix is a valid checkpoint:
   ``CalibrationCheckpointer`` persists it every N batches (atomically, via
   repro.checkpoint; serialized on a background thread by default so long
   passes never block on disk) and restores the newest valid one together
   with the batch cursor. Calibration batches are deterministic-by-index,
   so a restarted pass skips the consumed prefix and lands on identical
   statistics.

3. elastic re-mesh — ``remesh`` rebuilds the device mesh from the live
   device set; all shardings are axis-name-based (repro.distrib.sharding)
   so the job re-lowers for the surviving topology without code changes.
   Straggler mitigation falls out of the design: the only synchronization
   point is the psum inside the compiled step, and slow hosts delay but
   never deadlock; persistent stragglers are excluded at the next re-mesh.
"""
from __future__ import annotations

import logging
import time
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint)

log = logging.getLogger("repro.fault")


class CalibrationCheckpointer:
    """Periodic, atomic checkpoints of a calibration-statistics pytree.

    Plugs into ``CalibrationEngine.run(..., checkpointer=...)``: the engine
    calls ``restore`` once (returning the newest valid accumulator and the
    number of batches it already covers), ``maybe_save`` after every batch,
    and ``finish`` after the last one. Saves reuse repro.checkpoint's
    tmp-dir-rename protocol, so a host dying mid-save can never corrupt the
    newest checkpoint.

    **Async cadence** (default): ``maybe_save`` snapshots the accumulator
    to host (a synchronous ``device_get`` — cheap, and required anyway
    before the engine donates the buffers to the next step) and hands the
    serialization + atomic rename to ``checkpoint.AsyncCheckpointer``'s
    background thread, so a long calibration pass never blocks on disk
    between batches. At most one save is in flight (the next one joins the
    previous first); ``finish`` sync-flushes so the newest checkpoint is
    durable before the pass reports completion, and re-raises any write
    error the background thread hit. A restart racing an in-flight save is
    safe by construction: the tmp-dir-rename protocol means ``restore`` in
    a new process only ever sees complete checkpoints (the interrupted save
    is simply absent — tested in tests/test_one_traversal.py). Pass
    ``async_save=False`` for strictly synchronous saves.

    Sharded accumulators (the engine's ``mesh=`` mode) are **gathered on
    save**: ``save_checkpoint`` device_gets the pytree, which assembles
    each model-sharded Sigma into one host array on disk. Trade-off: the
    on-disk format stays mesh-independent and single-file-simple, at the
    cost of one host-side full-Sigma materialisation per save (bounded: one
    statistic tree, not one per unit group) — per-shard saves would avoid
    that peak but tie the checkpoint to the exact device layout. Restore
    re-places the gathered arrays shard-by-shard via the engine's
    ``stat_shardings``, so the resumed donated step starts from a correctly
    sharded accumulator. Despite the mesh-independent format, the engine's
    fingerprint *includes* the mesh layout: a checkpoint written under a
    different mesh is rejected (fresh start) because shard-local
    accumulation order differs and bitwise resume could not be guaranteed.
    """

    def __init__(self, ckpt_dir: str, every: int = 8,
                 async_save: bool = True, keep: int = 3):
        assert every >= 1, "checkpoint interval must be >= 1 batch"
        self.ckpt_dir = ckpt_dir
        self.every = every
        self._async = AsyncCheckpointer(ckpt_dir, keep=keep) \
            if async_save else None

    def restore(self, like, fingerprint: str = "", shardings=None):
        """-> (accumulator, n_batches_consumed); (like, 0) when fresh.

        fingerprint: the engine's configuration hash (phase + unit set +
        pass-2 plan + mesh layout when sharded). A checkpoint written under
        a different fingerprint — a reused directory from another
        sparsity/plan/model run, or the same pass on a different mesh — is
        ignored (fresh start) instead of silently resuming statistics that
        do not belong to this pass. Note the calibration *stream* is not
        fingerprinted: resuming assumes deterministic-by-index batches, as
        everywhere else in this runtime.

        shardings: optional NamedSharding pytree matching ``like`` (the
        engine's ``stat_shardings``); restored arrays are device_put with
        it so a sharded pass resumes with a correctly placed, donatable
        accumulator.
        """
        import json
        import os
        self.finish()          # never read under our own in-flight save
        last = latest_step(self.ckpt_dir)
        if last is None:
            return like, 0
        # check identity from the manifest BEFORE unflattening — a foreign
        # checkpoint may not even have this accumulator's tree structure
        man = os.path.join(self.ckpt_dir, f"step_{last:08d}",
                           "manifest.json")
        saved_fp = json.load(open(man)).get("extra", {}) \
            .get("fingerprint", "")
        if fingerprint and saved_fp != fingerprint:
            log.warning("calibration checkpoint in %s was written for a "
                        "different configuration (fingerprint %r != %r); "
                        "ignoring it and starting fresh", self.ckpt_dir,
                        saved_fp, fingerprint)
            return like, 0
        acc, _extra = restore_checkpoint(self.ckpt_dir, last, like)
        log.info("resumed calibration stats at batch %d", last)
        # back onto device so the engine can donate the buffers
        if shardings is not None:
            return jax.device_put(acc, shardings), last
        return jax.tree.map(jnp.asarray, acc), last

    def maybe_save(self, acc, n_batches: int, fingerprint: str = "",
                   force: bool = False):
        if force or n_batches % self.every == 0:
            extra = {"n_batches": n_batches, "fingerprint": fingerprint}
            if self._async is not None:
                # snapshot-to-host now (safe against buffer donation),
                # write + atomic rename on the background thread
                self._async.save(n_batches, acc, extra)
            else:
                from repro.checkpoint import save_checkpoint
                save_checkpoint(self.ckpt_dir, n_batches, acc, extra)

    def finish(self):
        """Sync-flush: block until the in-flight background save (if any)
        is durably on disk; re-raises its error. No-op in sync mode."""
        if self._async is not None:
            self._async.wait()


def run_with_restarts(make_state, step_fn, *, ckpt_dir: str,
                      total_steps: int, save_every: int,
                      max_restarts: int = 10, save_fn=None):
    """Generic restartable loop.

    make_state() -> state pytree (fresh);
    step_fn(state, step) -> state;
    save_fn(state, step) defaults to repro.checkpoint.save_checkpoint.
    """
    from repro.checkpoint import save_checkpoint
    save_fn = save_fn or (lambda st, s: save_checkpoint(ckpt_dir, s, st))
    restarts = 0
    while True:
        state = make_state()
        start = 0
        last = latest_step(ckpt_dir)
        if last is not None:
            state, _ = restore_checkpoint(ckpt_dir, last, state)
            start = last
            log.info("restored step %d", last)
        try:
            for step in range(start, total_steps):
                state = step_fn(state, step)
                if (step + 1) % save_every == 0 or step + 1 == total_steps:
                    save_fn(state, step + 1)
            return state
        except Exception as e:           # noqa: BLE001 — restart anything
            restarts += 1
            log.warning("step failed (%s); restart %d/%d", e, restarts,
                        max_restarts)
            if restarts > max_restarts:
                raise


class TolerantAccumulator:
    """Bounded-staleness statistics accumulation for CORP calibration.

    Accumulates linear statistics batch-by-batch; a batch whose computation
    raises (simulating a lost host / preempted slice) is dropped and the
    final statistics are re-weighted by the surviving sample count — the
    estimator stays unbiased because calibration batches are i.i.d.
    """

    def __init__(self, step_fn: Callable, params,
                 fail_hook: Optional[Callable[[int], None]] = None):
        self.step_fn = jax.jit(step_fn)
        self.params = params
        self.fail_hook = fail_hook
        self.total = None
        self.n_ok = 0
        self.n_failed = 0

    def run(self, batches: Iterable):
        from repro.core.stats import tree_add
        for i, batch in enumerate(batches):
            try:
                if self.fail_hook is not None:
                    self.fail_hook(i)     # may raise to simulate failure
                out = self.step_fn(self.params, batch)
            except Exception:             # noqa: BLE001
                self.n_failed += 1
                continue
            self.total = tree_add(self.total, out)
            self.n_ok += 1
        assert self.total is not None, "every calibration batch failed"
        return jax.device_get(self.total)


def remesh(shape_hint=None, axis_names=("data", "model")):
    """Build the largest mesh the *live* device set supports (elastic)."""
    devs = jax.devices()
    n = len(devs)
    if shape_hint is not None and int(np.prod(shape_hint)) <= n:
        shape = shape_hint
    else:
        # fall back: squarest 2-axis factorization of n
        a = int(np.sqrt(n))
        while n % a:
            a -= 1
        shape = (n // a, a)
    return jax.make_mesh(shape, axis_names[-len(shape):])
