"""Distributed runtime: sharding rules, collectives helpers, fault tolerance."""
