"""Sharding rules for params and activations.

Logical design (DESIGN.md §2.1):
  * mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod.
  * tensor parallelism over 'model' (Megatron column/row split; expert
    parallelism for MoE; head parallelism for attention where divisible).
  * optional FSDP (ZeRO-3) over ('pod','data') on a second dim for large
    models — params/optimizer state are all-gathered per scanned layer.
  * activations: batch over ('pod','data'); sequence-parallel residual over
    'model' when the shape allows (Megatron-SP, GSPMD inserts the gathers).

Everything is expressed against axis *names*, so re-meshing (elastic scaling)
re-lowers without code changes.

``constrain(x, kind)`` applies a with_sharding_constraint according to the
active activation policy (a context variable set by the launchers) and is a
no-op outside any policy — model code stays mesh-agnostic.

Calibration statistics get their own sharding contract (``stats_specs`` +
``CalibSharding``): per-unit second-moment/Gram blocks are column-sharded
over the model axis so a calibration pass never materialises a replicated
full Sigma on any device (see docs/calibration.md and
``repro.core.calibrate.CalibrationEngine``).

The serving slot cache likewise has its own contract
(``repro.serve.sharding``: ``slot_specs`` + ``ServeSharding``), which
composes with this module — a sharded ``ServeEngine`` places its params
via ``param_specs``/``shardings_of`` on the same mesh its cache splits
over. Both spec builders share the dict-mesh testability idiom pioneered
by ``stats_specs`` below.
"""
from __future__ import annotations

import contextlib
import threading
from typing import NamedTuple, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


# ---------------------------------------------------------------------------
# activation policy
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def activation_policy(rules: dict, mesh=None):
    """rules: {'residual': PartitionSpec | None, 'logits': ...}.

    ``mesh`` must be the concrete mesh the step lowers under: the abstract
    mesh is EMPTY inside ``with mesh:`` (verified), so divisibility checks
    need the real axis sizes — otherwise non-divisible constraints silently
    lower as padded shardings.
    """
    prev = getattr(_STATE, "rules", None)
    prev_mesh = getattr(_STATE, "mesh", None)
    _STATE.rules = rules
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.rules = prev
        _STATE.mesh = prev_mesh


def _spec_fits(x, spec) -> bool:
    mesh = getattr(_STATE, "mesh", None)
    if mesh is None:
        return False
    try:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    except Exception:
        return False
    for dim, names in enumerate(spec):
        if names is None:
            continue
        group = names if isinstance(names, tuple) else (names,)
        total = int(np.prod([sizes.get(n, 1) for n in group]))
        if total > 1 and (dim >= x.ndim or x.shape[dim] % total):
            return False
    return True


def constrain(x, kind: str):
    """Apply the active activation policy's sharding constraint to ``x``.

    Args:
      x: activation array (any rank).
      kind: rule key — 'residual', 'logits', 'mamba_inner', 'attn_qkv'
        (see ``make_activation_rules``).

    Returns ``x`` unchanged outside any policy, when the policy has no rule
    for ``kind``, or when the spec doesn't divide ``x``'s shape on the
    active mesh (never pads); otherwise ``with_sharding_constraint(x,
    spec)``. Model code calls this unconditionally and stays mesh-agnostic.
    """
    rules = getattr(_STATE, "rules", None)
    if not rules:
        return x
    spec = rules.get(kind)
    if spec is None or not _spec_fits(x, spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_qkv(q, k, v):
    """Head-shard q/k/v ALL-or-nothing: applying the layout to q alone when
    kv heads don't divide the model axis (GQA with few kv heads) forces a
    reshard inside attention — worse than no constraint (§Perf D2 note)."""
    rules = getattr(_STATE, "rules", None)
    if not rules:
        return q, k, v
    spec = rules.get("attn_qkv")
    if spec is None or not (_spec_fits(q, spec) and _spec_fits(k, spec)
                            and _spec_fits(v, spec)):
        return q, k, v
    c = jax.lax.with_sharding_constraint
    return c(q, spec), c(k, spec), c(v, spec)


def make_activation_rules(batch_axes=("data",), model_axis="model",
                          seq_shard=True):
    """Standard activation-sharding rule set for ``activation_policy``.

    Args:
      batch_axes: mesh axes the batch dim shards over (tuple).
      model_axis: tensor-parallel axis name.
      seq_shard: sequence-parallel residual (Megatron-SP) when True.

    Returns ``{kind: PartitionSpec}`` for 'residual' (B, T, D),
    'logits' (B, T, V), 'mamba_inner' (B, T, d_inner) and
    'attn_qkv' (B, T, H, d) — the keys ``constrain``/``constrain_qkv``
    look up.
    """
    resid = P(batch_axes, model_axis if seq_shard else None, None)
    return {
        "residual": resid,
        "logits": P(batch_axes, None, model_axis),
        # mamba inner activations: channel-sharded over 'model', sequence-
        # unsharded — the per-channel recurrence needs zero cross-chip
        # traffic (§Perf J1). A batch-over-(data x model) variant was tried
        # and REFUTED: the residual reshard at every mamba/attention boundary
        # cost far more than it saved (EXPERIMENTS.md §Perf J4).
        "mamba_inner": P(batch_axes, None, model_axis),
        # attention q/k/v (B,T,H,d): heads over 'model', sequence gathered —
        # with a sequence-sharded residual the layout change lowers to an
        # all-to-all (constant per-chip bytes) instead of K/V all-gathers
        # (§Perf iteration D2)
        "attn_qkv": P(batch_axes, None, model_axis, None),
    }


# ---------------------------------------------------------------------------
# parameter sharding
# ---------------------------------------------------------------------------

def _divisible(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _spec_for(path: str, shape, model_size: int, fsdp_axes, fsdp_size: int,
              scanned: bool):
    """Return a PartitionSpec for one parameter array."""
    dims = list(shape)
    off = 1 if scanned else 0   # leading scan/reps axis is never sharded
    body = dims[off:]
    spec = [None] * len(dims)

    def assign(i, name):
        spec[off + i] = name

    leaf = path.rsplit("/", 1)[-1]

    model_dim = None
    # priority: expert axis > head axis > wide/output axis > input axis
    if leaf in ("wg", "wu", "wd") and len(body) == 3:        # MoE (E, D, F)
        if _divisible(body[0], model_size):
            model_dim = 0
    elif leaf in ("wq", "wk", "wv") and len(body) == 3:      # (D, H, dq)
        if _divisible(body[1], model_size):
            model_dim = 1
        elif _divisible(body[0], model_size):
            model_dim = 0
    elif leaf == "wo" and len(body) == 3:                    # (H, dv, D)
        if _divisible(body[0], model_size):
            model_dim = 0
        elif _divisible(body[2], model_size):
            model_dim = 2
    elif leaf in ("w_uq_nope", "w_uq_rope", "w_uk_nope", "w_uv") \
            and len(body) == 3:                              # (r, H, d)
        if _divisible(body[1], model_size):
            model_dim = 1
    elif leaf in ("embed", "head") and len(body) == 2:
        # vocab-sharded (vocab padded to a multiple of the model axis)
        vdim = 0 if body[0] >= body[1] else 1
        if _divisible(body[vdim], model_size):
            model_dim = vdim
    elif leaf == "router":
        model_dim = None
    elif len(body) == 2:
        # generic linear: shard the wider dim on 'model'
        cand = 0 if body[0] >= body[1] else 1
        if _divisible(body[cand], model_size):
            model_dim = cand
        elif _divisible(body[1 - cand], model_size):
            model_dim = 1 - cand
    elif len(body) == 1:
        model_dim = None

    if model_dim is not None:
        assign(model_dim, "model")

    if fsdp_axes and fsdp_size > 1:
        for i, d in enumerate(body):
            if spec[off + i] is None and len(body) >= 2 \
                    and _divisible(d, fsdp_size):
                assign(i, fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0])
                break
    return P(*spec)


def param_specs(params, mesh: Mesh, *, fsdp: bool = False):
    """PartitionSpec pytree matching ``params``.

    Args:
      params: parameter pytree (real arrays or ``jax.eval_shape`` output —
        only ``.shape`` is inspected, so abstract trees work).
      mesh: target mesh; axis sizes gate divisibility (a dim that doesn't
        divide the 'model' axis is left unsharded rather than padded).
      fsdp: additionally shard one remaining dim of every >=2-D param over
        ('pod','data') — ZeRO-3 style parameter sharding.

    Returns:
      A pytree of ``PartitionSpec`` with the same structure as ``params``;
      feed it to ``shardings_of`` for ``NamedSharding`` leaves.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)
    fsdp_axes = tuple(a for a in ("pod", "data") if a in sizes) if fsdp else ()
    fsdp_size = int(np.prod([sizes[a] for a in fsdp_axes])) if fsdp_axes else 1

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        scanned = "/p" in path and any(
            seg.startswith("p") and seg[1:].isdigit()
            for seg in path.split("/"))
        specs.append(_spec_for(path, leaf.shape, model_size, fsdp_axes,
                               fsdp_size, scanned))
    return jax.tree_util.tree_unflatten(treedef, specs)


def shardings_of(specs, mesh: Mesh):
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh`` (specs are
    treated as leaves, so nested dict/list structures pass through)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def batch_specs(batch_tree, mesh: Mesh):
    """Shard every batch array's leading (batch) dim over ('pod','data').

    Arrays whose leading dim doesn't divide the data-parallel world size are
    left replicated (never padded). Returns a PartitionSpec pytree matching
    ``batch_tree``.
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    def f(x):
        spec = [None] * x.ndim
        if x.ndim >= 1 and x.shape[0] % int(
                np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                         for a in axes])) == 0:
            spec[0] = axes if len(axes) > 1 else axes[0]
        return P(*spec)
    return jax.tree.map(f, batch_tree)


# ---------------------------------------------------------------------------
# calibration-statistics sharding
# ---------------------------------------------------------------------------

class CalibSharding(NamedTuple):
    """How a calibration pass is laid out on a mesh.

    mesh: the device mesh the fused statistics step runs under.
    model_axis: mesh axis partitioning per-unit covariance/Gram columns.
    batch_axes: mesh axes the calibration batch is sharded over; per-batch
      partial sums reduce over these via psum inside the compiled step.
    """
    mesh: Mesh
    model_axis: str = "model"
    batch_axes: Tuple[str, ...] = ("pod", "data")

    @property
    def sizes(self):
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    @property
    def model_size(self) -> int:
        return self.sizes.get(self.model_axis, 1)

    @property
    def present_batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.batch_axes if a in self.sizes)


# Stat leaves that stay replicated: scalar-ish bookkeeping whose size never
# grows with the unit width (sample counts, pruned-tail energies, and the
# one-traversal engine's per-group Frobenius totals).
_REPLICATED_STATS = frozenset({"n", "t2", "t2_tot"})


def stats_specs(stats, mesh, *, model_axis: str = "model"):
    """PartitionSpecs for a calibration-statistics pytree.

    Every per-unit statistic leaf whose trailing dim divides the model-axis
    size is sharded on that trailing dim over ``model_axis`` — for a second
    moment ``s2: (F, F)`` that is column sharding, so each device holds an
    (F, F/m) slab and no device ever allocates a replicated full Sigma.
    Sample counts ``n`` and pruned-tail energies ``t2`` stay replicated
    (they are O(1) per unit). Leading stack/expert/group dims are never
    sharded.

    Args:
      stats: statistics pytree (arrays or ``jax.eval_shape`` structs; only
        ``.shape``/``.ndim`` are inspected). Leaf *names* (the innermost
        dict key: 's2', 's1', 'na', 'rank', 'G', 'h', 'n', 't2', and the
        speculative one-traversal leaves 'Gc', 'Hfull', 'hfull', 't2_tot')
        choose the rule; unknown wide leaves get the default
        trailing-dim-over-model treatment when divisible.
      mesh: a ``jax.sharding.Mesh`` — or a plain ``{axis: size}`` dict,
        which makes the rule testable without devices.
      model_axis: mesh axis name to shard over.

    Returns:
      PartitionSpec pytree matching ``stats``.

    >>> tree = {"blk/mlp": {"s2": np.zeros((3, 8, 8)), "s1": np.zeros((3, 8)),
    ...                     "n": np.zeros((3,))}}
    >>> specs = stats_specs(tree, {"data": 2, "model": 4})
    >>> specs["blk/mlp"]["s2"] == P(None, None, "model")
    True
    >>> specs["blk/mlp"]["s1"] == P(None, "model")
    True
    >>> specs["blk/mlp"]["n"] == P()     # counts stay replicated
    True
    >>> stats_specs(tree, {"data": 2, "model": 3})["blk/mlp"]["s2"] == P()
    True
    """
    sizes = mesh if isinstance(mesh, dict) else \
        dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get(model_axis, 1)

    flat = jax.tree_util.tree_flatten_with_path(stats)[0]
    treedef = jax.tree_util.tree_structure(stats)
    specs = []
    for kp, leaf in flat:
        name = str(getattr(kp[-1], "key", getattr(kp[-1], "idx", kp[-1])))
        if (m <= 1 or leaf.ndim == 0 or name in _REPLICATED_STATS
                or leaf.shape[-1] % m or leaf.shape[-1] < m):
            specs.append(P())
        else:
            specs.append(P(*([None] * (leaf.ndim - 1)), model_axis))
    return jax.tree_util.tree_unflatten(treedef, specs)

