"""Sharding rules for params and activations.

Logical design (DESIGN.md §2.1):
  * mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod.
  * tensor parallelism over 'model' (Megatron column/row split; expert
    parallelism for MoE; head parallelism for attention where divisible).
  * optional FSDP (ZeRO-3) over ('pod','data') on a second dim for large
    models — params/optimizer state are all-gathered per scanned layer.
  * activations: batch over ('pod','data'); sequence-parallel residual over
    'model' when the shape allows (Megatron-SP, GSPMD inserts the gathers).

Everything is expressed against axis *names*, so re-meshing (elastic scaling)
re-lowers without code changes.

``constrain(x, kind)`` applies a with_sharding_constraint according to the
active activation policy (a context variable set by the launchers) and is a
no-op outside any policy — model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


# ---------------------------------------------------------------------------
# activation policy
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def activation_policy(rules: dict, mesh=None):
    """rules: {'residual': PartitionSpec | None, 'logits': ...}.

    ``mesh`` must be the concrete mesh the step lowers under: the abstract
    mesh is EMPTY inside ``with mesh:`` (verified), so divisibility checks
    need the real axis sizes — otherwise non-divisible constraints silently
    lower as padded shardings.
    """
    prev = getattr(_STATE, "rules", None)
    prev_mesh = getattr(_STATE, "mesh", None)
    _STATE.rules = rules
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.rules = prev
        _STATE.mesh = prev_mesh


def _spec_fits(x, spec) -> bool:
    mesh = getattr(_STATE, "mesh", None)
    if mesh is None:
        return False
    try:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    except Exception:
        return False
    for dim, names in enumerate(spec):
        if names is None:
            continue
        group = names if isinstance(names, tuple) else (names,)
        total = int(np.prod([sizes.get(n, 1) for n in group]))
        if total > 1 and (dim >= x.ndim or x.shape[dim] % total):
            return False
    return True


def constrain(x, kind: str):
    rules = getattr(_STATE, "rules", None)
    if not rules:
        return x
    spec = rules.get(kind)
    if spec is None or not _spec_fits(x, spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_qkv(q, k, v):
    """Head-shard q/k/v ALL-or-nothing: applying the layout to q alone when
    kv heads don't divide the model axis (GQA with few kv heads) forces a
    reshard inside attention — worse than no constraint (§Perf D2 note)."""
    rules = getattr(_STATE, "rules", None)
    if not rules:
        return q, k, v
    spec = rules.get("attn_qkv")
    if spec is None or not (_spec_fits(q, spec) and _spec_fits(k, spec)
                            and _spec_fits(v, spec)):
        return q, k, v
    c = jax.lax.with_sharding_constraint
    return c(q, spec), c(k, spec), c(v, spec)


def make_activation_rules(batch_axes=("data",), model_axis="model",
                          seq_shard=True):
    resid = P(batch_axes, model_axis if seq_shard else None, None)
    return {
        "residual": resid,
        "logits": P(batch_axes, None, model_axis),
        # mamba inner activations: channel-sharded over 'model', sequence-
        # unsharded — the per-channel recurrence needs zero cross-chip
        # traffic (§Perf J1). A batch-over-(data x model) variant was tried
        # and REFUTED: the residual reshard at every mamba/attention boundary
        # cost far more than it saved (EXPERIMENTS.md §Perf J4).
        "mamba_inner": P(batch_axes, None, model_axis),
        # attention q/k/v (B,T,H,d): heads over 'model', sequence gathered —
        # with a sequence-sharded residual the layout change lowers to an
        # all-to-all (constant per-chip bytes) instead of K/V all-gathers
        # (§Perf iteration D2)
        "attn_qkv": P(batch_axes, None, model_axis, None),
    }


# ---------------------------------------------------------------------------
# parameter sharding
# ---------------------------------------------------------------------------

def _divisible(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _spec_for(path: str, shape, model_size: int, fsdp_axes, fsdp_size: int,
              scanned: bool):
    """Return a PartitionSpec for one parameter array."""
    dims = list(shape)
    off = 1 if scanned else 0   # leading scan/reps axis is never sharded
    body = dims[off:]
    spec = [None] * len(dims)

    def assign(i, name):
        spec[off + i] = name

    leaf = path.rsplit("/", 1)[-1]

    model_dim = None
    # priority: expert axis > head axis > wide/output axis > input axis
    if leaf in ("wg", "wu", "wd") and len(body) == 3:        # MoE (E, D, F)
        if _divisible(body[0], model_size):
            model_dim = 0
    elif leaf in ("wq", "wk", "wv") and len(body) == 3:      # (D, H, dq)
        if _divisible(body[1], model_size):
            model_dim = 1
        elif _divisible(body[0], model_size):
            model_dim = 0
    elif leaf == "wo" and len(body) == 3:                    # (H, dv, D)
        if _divisible(body[0], model_size):
            model_dim = 0
        elif _divisible(body[2], model_size):
            model_dim = 2
    elif leaf in ("w_uq_nope", "w_uq_rope", "w_uk_nope", "w_uv") \
            and len(body) == 3:                              # (r, H, d)
        if _divisible(body[1], model_size):
            model_dim = 1
    elif leaf in ("embed", "head") and len(body) == 2:
        # vocab-sharded (vocab padded to a multiple of the model axis)
        vdim = 0 if body[0] >= body[1] else 1
        if _divisible(body[vdim], model_size):
            model_dim = vdim
    elif leaf == "router":
        model_dim = None
    elif len(body) == 2:
        # generic linear: shard the wider dim on 'model'
        cand = 0 if body[0] >= body[1] else 1
        if _divisible(body[cand], model_size):
            model_dim = cand
        elif _divisible(body[1 - cand], model_size):
            model_dim = 1 - cand
    elif len(body) == 1:
        model_dim = None

    if model_dim is not None:
        assign(model_dim, "model")

    if fsdp_axes and fsdp_size > 1:
        for i, d in enumerate(body):
            if spec[off + i] is None and len(body) >= 2 \
                    and _divisible(d, fsdp_size):
                assign(i, fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0])
                break
    return P(*spec)


def param_specs(params, mesh: Mesh, *, fsdp: bool = False):
    """PartitionSpec pytree matching ``params`` (works on eval_shape trees)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)
    fsdp_axes = tuple(a for a in ("pod", "data") if a in sizes) if fsdp else ()
    fsdp_size = int(np.prod([sizes[a] for a in fsdp_axes])) if fsdp_axes else 1

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        scanned = "/p" in path and any(
            seg.startswith("p") and seg[1:].isdigit()
            for seg in path.split("/"))
        specs.append(_spec_for(path, leaf.shape, model_size, fsdp_axes,
                               fsdp_size, scanned))
    return jax.tree_util.tree_unflatten(treedef, specs)


def shardings_of(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def batch_specs(batch_tree, mesh: Mesh):
    """Shard every batch array's leading dim over ('pod','data')."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    def f(x):
        spec = [None] * x.ndim
        if x.ndim >= 1 and x.shape[0] % int(
                np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                         for a in axes])) == 0:
            spec[0] = axes if len(axes) > 1 else axes[0]
        return P(*spec)
    return jax.tree.map(f, batch_tree)
