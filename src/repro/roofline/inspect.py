import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""HLO inspector: top collectives and largest buffers for one dry-run cell.

    PYTHONPATH=src python -m repro.roofline.inspect --arch granite-8b \
        --shape decode_32k --mesh single [--sparsity 0.5]
"""
import argparse      # noqa: E402
import collections   # noqa: E402
import re            # noqa: E402

from repro.roofline.analysis import _OP_RE, _shape_bytes  # noqa: E402


def top_collectives(text, k=12):
    agg = collections.Counter()
    for m in _OP_RE.finditer(text):
        shapes, kind = m.group(1), m.group(2)
        line_end = text.find("\n", m.end())
        line = text[max(0, m.start() - 120):line_end]
        op_name = ""
        nm = re.search(r'op_name="([^"]+)"', text[m.end():line_end])
        if nm:
            op_name = nm.group(1)[-90:]
        agg[(kind, _shape_bytes(shapes), op_name)] += 1
    rows = sorted(((b * c, kind, b, c, nm)
                   for (kind, b, nm), c in agg.items()), reverse=True)
    return rows[:k]


def big_buffers(text, k=12):
    sizes = collections.Counter()
    for m in re.finditer(r"=\s*([a-z0-9]+\[[0-9,]*\])[^ ]*\s+(\S+)\(", text):
        b = _shape_bytes(m.group(1))
        if b > (1 << 28):
            sizes[(m.group(2)[:20], m.group(1))] += 1
    return sorted(((b_ := _shape_bytes(sh)) * c, op, sh, c)
                  for (op, sh), c in sizes.items())[::-1][:k]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--sparsity", type=float, default=0.0)
    args = ap.parse_args()

    from repro.launch.dryrun import build_lowering
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    lowered, cfg, shape, lflops = build_lowering(args.arch, args.shape, mesh,
                                                 sparsity=args.sparsity)
    with mesh:
        compiled = lowered.compile()
    text = compiled.as_text()
    print("== top collectives (bytes x count) ==")
    for total, kind, b, c, nm in top_collectives(text):
        print(f"{total/1e9:9.3f} GB  {kind:18s} {b/1e6:10.1f}MB x{c:3d}  {nm}")
    print("== largest buffers ==")
    for total, op, sh, c in big_buffers(text):
        print(f"{total/1e9:9.3f} GB  {op:20s} {sh} x{c}")
    mem = compiled.memory_analysis()
    print(f"peak: args={mem.argument_size_in_bytes/1e9:.1f}GB "
          f"temp={mem.temp_size_in_bytes/1e9:.1f}GB")


if __name__ == "__main__":
    main()
