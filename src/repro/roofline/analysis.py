"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), in seconds:
    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (the SPMD-partitioned
module is the per-device program, so its costs are per-chip);
collective bytes are NOT in cost_analysis — we parse the optimized HLO text
and sum the output bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (wire-byte approximations noted inline).

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import logging
import re
from typing import Dict

import jax
import numpy as np

log = logging.getLogger("repro.roofline")


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16
    hbm_bw: float = 819e9
    link_bw: float = 50e9           # per ICI link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.:  %all-reduce.5 = f32[128,1024]{1,0} all-reduce(...)
#                ROOT %r = (bf16[8,16]{...}, f32[4]) all-to-all(...)
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


_WARNED_DTYPES: set = set()


def _shape_bytes(shapes_str: str) -> int:
    """Total bytes of every typed shape in an HLO shape string.

    Dtypes missing from ``_DTYPE_BYTES`` (e.g. ``f8e4m3`` on fp8-quantised
    modules) are counted with a conservative 1-byte-per-element floor and
    warned once per dtype — silently dropping them undercounted collective
    traffic for any extended-dtype model.
    """
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            if dt not in _WARNED_DTYPES:
                _WARNED_DTYPES.add(dt)
                log.warning(
                    "roofline: unknown HLO dtype %r — counting 1 byte/elem "
                    "(add it to _DTYPE_BYTES for exact accounting)", dt)
            nbytes = 1
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device output bytes of every collective, by op kind.

    'start' variants only (async pairs would double count); 'done' lines
    don't match because their operand is the start tuple.
    """
    out = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shapes)
    return out


def analyze_compiled(compiled, hw: HW = HW(), *, n_devices: int = 1,
                     logical_flops: float | None = None) -> Dict:
    """Roofline terms from a compiled (SPMD-partitioned) executable.

    XLA's cost_analysis counts while-loop (lax.scan) bodies ONCE, not
    trip_count times (verified empirically) — fatal for scan-over-layers
    models. When ``logical_flops`` (exact jaxpr-level matmul flops, see
    ``jaxpr_matmul_flops``) is provided, the compute term uses it directly
    and the memory/collective terms are scaled by the resulting undercount
    factor (exact when the loop body dominates, which it does for every
    assigned model; raw values are reported alongside).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byac = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    coll_total = float(sum(coll.values()))
    factor = 1.0
    if logical_flops is not None and flops > 0:
        factor = max(1.0, (logical_flops / n_devices) / flops)
        flops_corr = logical_flops / n_devices
    else:
        flops_corr = flops
    byac_corr = byac * factor
    coll_corr = coll_total * factor
    terms = {
        "flops_per_device": flops_corr,
        "bytes_per_device": byac_corr,
        "collective_bytes_per_device": coll_corr,
        "collectives": coll,
        "raw_cost_analysis": {"flops": flops, "bytes": byac,
                              "collective_bytes": coll_total},
        "scan_undercount_factor": factor,
        "t_compute": flops_corr / hw.peak_flops,
        "t_memory": byac_corr / hw.hbm_bw,
        "t_collective": coll_corr / hw.link_bw,
    }
    terms["bottleneck"] = max(
        ("compute", "memory", "collective"),
        key=lambda k: terms[f"t_{k}"])
    try:
        mem = compiled.memory_analysis()
        arg_b = int(getattr(mem, "argument_size_in_bytes", 0))
        out_b = int(getattr(mem, "output_size_in_bytes", 0))
        alias_b = int(getattr(mem, "alias_size_in_bytes", 0))
        terms["memory_analysis"] = {
            "argument_bytes": arg_b,
            "output_bytes": out_b,
            "alias_bytes": alias_b,
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "temp_size_in_bytes", 0)) + arg_b,
        }
        # analytic lower bound on HBM traffic: every live argument read once
        # + every (non-aliased) output written once. Brackets the HLO-derived
        # upper bound, which on the CPU backend includes bf16->f32 dot-input
        # conversions that the TPU MXU performs in-flight (DESIGN.md §5).
        terms["t_memory_lb"] = (arg_b + out_b - alias_b) / hw.hbm_bw
    except Exception as e:      # noqa: BLE001
        terms["memory_analysis"] = {"error": str(e)}
    return terms


# ---------------------------------------------------------------------------
# exact logical (global) matmul flops from the jaxpr
# ---------------------------------------------------------------------------

def _prod(xs):
    n = 1
    for x in xs:
        n *= int(x)
    return n


def _eqn_flops(eqn) -> float:
    name = eqn.primitive.name
    if name == "dot_general":
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lhs = eqn.invars[0].aval.shape
        rhs = eqn.invars[1].aval.shape
        batch = _prod(lhs[d] for d in lb)
        contract = _prod(lhs[d] for d in lc)
        m = _prod(lhs[d] for d in range(len(lhs))
                  if d not in lb and d not in lc)
        n = _prod(rhs[d] for d in range(len(rhs))
                  if d not in rb and d not in rc)
        return 2.0 * batch * m * n * contract
    if name == "conv_general_dilated":
        out = _prod(eqn.outvars[0].aval.shape)
        rhs = eqn.invars[1].aval.shape
        return 2.0 * out * _prod(rhs[:-1])
    return 0.0


def _sub_jaxprs(eqn):
    for key in ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                "fun_jaxpr"):
        if key in eqn.params:
            yield eqn.params[key], 1
    if "branches" in eqn.params:
        for br in eqn.params["branches"]:
            yield br, 1


def _count_jaxpr(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            total += eqn.params["length"] * _count_jaxpr(
                eqn.params["jaxpr"].jaxpr)
        elif name == "while":
            total += _count_jaxpr(eqn.params["body_jaxpr"].jaxpr)
        elif name == "cond":
            total += max((_count_jaxpr(br.jaxpr)
                          for br in eqn.params["branches"]), default=0.0)
        else:
            f = _eqn_flops(eqn)
            if f:
                total += f
            else:
                for sub, mult in _sub_jaxprs(eqn):
                    j = getattr(sub, "jaxpr", sub)
                    total += mult * _count_jaxpr(j)
    return total


def jaxpr_matmul_flops(fn, *args) -> float:
    """Exact global matmul/conv flops of fn(*args) — recurses through scan
    with trip counts (the MFU-convention numerator's denominator twin)."""
    closed = jax.make_jaxpr(fn)(*args)
    return _count_jaxpr(closed.jaxpr)


# ---------------------------------------------------------------------------
# model flops (the "useful work" numerator)
# ---------------------------------------------------------------------------

def params_count(cfg) -> Dict[str, float]:
    """Exact parameter counts from the init tree (eval_shape — no alloc)."""
    from repro.models import build_model
    model = build_model(cfg)
    tree = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = 0
    active = 0
    E = cfg.moe.num_experts if cfg.moe is not None else 0
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        n = int(np.prod(leaf.shape))
        total += n
        if E and "/mlp/" in path and leaf.ndim >= 3 \
                and E in leaf.shape and "shared" not in path \
                and "router" not in path:
            n = n * cfg.moe.top_k // E
        active += n
    return {"total": float(total), "active": float(active)}


def model_flops(cfg, shape) -> float:
    """6·N·D for train, 2·N·D for forward-only (N = active params,
    D = processed tokens)."""
    pc = params_count(cfg)
    n_act = pc["active"]
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                 else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_act * toks
