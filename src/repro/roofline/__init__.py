from repro.roofline.analysis import (HW, analyze_compiled, model_flops,
                                     params_count)

__all__ = ["HW", "analyze_compiled", "model_flops", "params_count"]
