"""AdamW with ZeRO-shardable state, dtype knobs, masks, global clipping.

State is a pytree shaped like the params (plus a scalar step), so the same
param_specs sharding applies — on the production mesh the optimizer state is
FSDP-sharded over ('pod','data') for the large configs (DESIGN.md §2.1).

Masks:
  * no weight decay on 1D params (norm scales, biases) and embeddings.
  * frozen buffers (rope frequency tables 'rope_inv*') receive no update.

dtype knobs: ``m_dtype='bfloat16'`` halves optimizer memory for the
600B-class configs (napkin math in DESIGN.md §2.1); v stays fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    m_dtype: str = "float32"
    v_dtype: str = "float32"


def _path_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


def _frozen(path: str) -> bool:
    return "rope_inv" in path


def _decayed(path: str, leaf) -> bool:
    if leaf.ndim <= 1:
        return False
    if "embed" in path or "pos" in path or "cls" in path:
        return False
    return True


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()):
    def mk(dtype):
        return lambda p: jnp.zeros(p.shape, jnp.dtype(dtype))
    return {
        "m": jax.tree.map(mk(cfg.m_dtype), params),
        "v": jax.tree.map(mk(cfg.v_dtype), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, lr, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    step = state["step"] + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    flat_p = jax.tree_util.tree_flatten_with_path(params)
    paths = [_path_str(kp) for kp, _ in flat_p[0]]
    treedef = flat_p[1]
    leaves_p = [x for _, x in flat_p[0]]
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state["m"])
    leaves_v = treedef.flatten_up_to(state["v"])

    new_p, new_m, new_v = [], [], []
    for path, p, g, m, v in zip(paths, leaves_p, leaves_g, leaves_m,
                                leaves_v):
        if _frozen(path):
            new_p.append(p)
            new_m.append(m)
            new_v.append(v)
            continue
        gf = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        upd = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        if cfg.weight_decay > 0 and _decayed(path, p):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(mf.astype(m.dtype))
        new_v.append(vf.astype(v.dtype))

    return (jax.tree_util.tree_unflatten(treedef, new_p),
            {"m": jax.tree_util.tree_unflatten(treedef, new_m),
             "v": jax.tree_util.tree_unflatten(treedef, new_v),
             "step": step},
            {"grad_norm": gnorm})
