from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "warmup_cosine"]
