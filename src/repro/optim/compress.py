"""Error-feedback int8 gradient compression (1-bit-Adam / EF-SGD family).

At 1000+-node scale the data-parallel gradient reduce-scatter is a fixed
wire cost per step; int8 quantization with error feedback cuts it 4x vs
fp32 (2x vs bf16) with provably bounded bias (the residual is re-injected
next step, so the compressed estimator telescopes).

The quantize/dequantize pair below is the *algorithm*; on a real cluster it
wraps the gradient tree immediately before the psum (the dry-run lowers the
int8 all-reduce when REPRO_GRAD_COMPRESS=1). Convergence is validated in
tests/test_substrate.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    """Error-feedback residual state (same tree as params, fp32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef_state):
    """Returns (compressed int8 tree + scales, new ef_state).

    The int8 tree is what crosses the wire (psum of int8 values upcast to
    int32 accumulators on real hardware); the residual x - dq(q(x)) feeds
    back into the next step.
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _q8(x)
        resid = x - _dq8(q, scale)
        return (q, scale), resid

    flat, treedef = jax.tree_util.tree_flatten(grads)
    eflat = treedef.flatten_up_to(ef_state)
    qs, resids = zip(*[one(g, e) for g, e in zip(flat, eflat)])
    return (jax.tree_util.tree_unflatten(treedef, [q for q, _ in qs]),
            jax.tree_util.tree_unflatten(treedef, [s for _, s in qs])), \
        jax.tree_util.tree_unflatten(treedef, list(resids))


def decompress_grads(compressed):
    qt, st = compressed
    return jax.tree.map(lambda q, s: _dq8(q, s), qt, st)


def ef_round_trip(grads, ef_state):
    """Quantize -> (wire) -> dequantize with error feedback carried."""
    compressed, new_ef = compress_grads(grads, ef_state)
    return decompress_grads(compressed), new_ef
