"""Pallas TPU kernels for the CORP framework.

Each kernel package provides:
  <name>.py - pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py    - jit'd public wrapper with backend dispatch (pallas on TPU,
              memory-sane XLA implementation elsewhere, interpret for tests)
  ref.py    - pure-jnp oracle used by the test suite

Kernels:
  flash_attention - blockwise online-softmax attention (calibration forward,
                    prefill, training) — the dominant non-GEMM compute.
  gram            - streaming second-moment (X^T X) accumulation — CORP's
                    calibration statistics hot-spot (Alg. 3/5 inputs).
  wkv6            - RWKV-6 chunked linear-attention recurrence (rwkv6-3b arch).
  flash_decode    - split-KV single-token decode attention (FlashDecoding) —
                    the memory-bound serving hot path the paper's pruning targets.
"""
