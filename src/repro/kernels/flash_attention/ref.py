"""Pure-jnp oracle for flash attention (GQA, causal / sliding-window / full).

Materializes the full (T, S) logit matrix in fp32 — only suitable for small
shapes; this is the ground truth the Pallas kernel and the chunked XLA
implementation are validated against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None):
    """q: (B,T,H,dq), k: (B,S,Hkv,dq), v: (B,S,Hkv,dv) -> (B,T,H,dv)."""
    B, T, H, dq = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(dq).astype(jnp.float32)
    qg = q.reshape(B, T, Hkv, g, dq).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("btngq,bsnq->bngts", qg, kf) * scale
    qi = jnp.arange(T)[:, None] + (S - T)   # right-aligned query positions
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (ki > qi - window)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bngts,bsnv->btngv", w, v.astype(jnp.float32))
    return o.reshape(B, T, H, -1).astype(q.dtype)
