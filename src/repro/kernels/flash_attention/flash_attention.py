"""Pallas TPU flash attention (forward) with explicit BlockSpec VMEM tiling.

Blockwise online-softmax attention over (q_block, kv_block) tiles:
  grid = (batch, q_heads, num_q_blocks, num_kv_blocks)  [kv innermost]
  VMEM scratch carries the running (max, denom, accumulator) across the kv
  grid dimension; the output tile is written once on the last kv block.

GQA is handled by the k/v index maps (query head h reads kv head h // g).
The kernel targets the TPU MXU (block dims padded to multiples of 128 by the
caller); on CPU it runs under ``interpret=True`` for validation against
``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, bq, bk, t, s, nk):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, dq)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, dq)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, dv)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    # absolute positions (right-aligned queries for q_len < kv_len)
    qi = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (s - t)
    ki = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (ki > qi - window)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]                           # (bq, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                   # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    bq=128, bk=128, interpret=False):
    """q: (B,T,H,dq), k: (B,S,Hkv,dq), v: (B,S,Hkv,dv) -> (B,T,H,dv)."""
    B, T, H, dq = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = H // Hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(dq))
    bq = min(bq, T)
    bk = min(bk, S)
    assert T % bq == 0 and S % bk == 0, "block sizes must divide T/S"
    nq, nk = T // bq, S // bk

    # (B, H, T, dq) layout for contiguous head tiles
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, t=T, s=S, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dq), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dq), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, dv), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
