from repro.kernels.flash_attention import ops, ref
from repro.kernels.flash_attention.ops import attention

__all__ = ["ops", "ref", "attention"]
