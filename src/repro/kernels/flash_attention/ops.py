"""Public attention op with backend dispatch.

impl resolution (env ``REPRO_ATTN_IMPL`` overrides):
  * 'pallas'  : Pallas TPU kernel (forward) — selected on TPU backends.
  * 'xla'     : memory-sane chunked online-softmax attention in pure jnp
                (lax.scan over q- and kv-chunks) — selected on CPU/GPU and
                used for all dry-run lowering. Never materializes the full
                (T, S) logit matrix.
  * 'ref'     : small-shape oracle (full logits) — picked automatically for
                tiny inputs where chunking is pointless.
  * 'interpret': Pallas kernel under interpret=True (kernel tests).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.flash_attention import flash_attention

_SMALL = 1 << 20  # T*S below this: just use the oracle


def _resolve_impl(T: int, S: int, bq: int, bk: int) -> str:
    impl = os.environ.get("REPRO_ATTN_IMPL", "")
    if impl:
        return impl
    if jax.default_backend() == "tpu":
        return "pallas"
    if T * S <= _SMALL or T % min(bq, T) or S % min(bk, S):
        return "ref"
    return "xla"


def attention(q, k, v, *, causal=True, window=None, scale=None,
              impl=None, bq=512, bk=1024):
    """q: (B,T,H,dq), k: (B,S,Hkv,dq), v: (B,S,Hkv,dv) -> (B,T,H,dv)."""
    B, T, H, dq = q.shape
    S = k.shape[1]
    if scale is None:
        scale = float(1.0 / np.sqrt(dq))
    impl = impl or _resolve_impl(T, S, bq, bk)
    if impl == "ref":
        return _ref.attention(q, k, v, causal=causal, window=window,
                              scale=scale)
    if impl in ("pallas", "interpret"):
        pbq = min(128, T) if T % min(128, T) == 0 else T
        pbk = min(128, S) if S % min(128, S) == 0 else S
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, bq=pbq, bk=pbk,
                               interpret=(impl == "interpret"))
    return _chunked(q, k, v, causal=causal, window=window, scale=scale,
                    bq=min(bq, T), bk=min(bk, S))


# ---------------------------------------------------------------------------
# chunked XLA implementation (online softmax over kv chunks)
# ---------------------------------------------------------------------------

def _chunked(q, k, v, *, causal, window, scale, bq, bk):
    B, T, H, dq = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = H // Hkv
    nq, nk = T // bq, S // bk

    # chunk-major layouts for scan
    qc = q.reshape(B, nq, bq, Hkv, g, dq).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(B, nk, bk, Hkv, dq).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nk, bk, Hkv, dv).transpose(1, 0, 3, 2, 4)
    off = S - T  # right-aligned queries

    def q_step(_, qi_i):
        qi, i = qi_i            # (B,Hkv,g,bq,dq), scalar chunk index
        m0 = jnp.full((B, Hkv, g, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, bq, dv), jnp.float32)

        def kv_step(carry, kv_j):
            m, l, acc = carry
            kj, vj, j = kv_j

            def skip(operand):
                return operand[0], operand[1], operand[2]

            def compute(operand):
                m, l, acc = operand
                logits = jnp.einsum(
                    "bngqd,bnkd->bngqk", qi.astype(jnp.float32),
                    kj.astype(jnp.float32)) * scale
                qpos = (i * bq + jnp.arange(bq) + off)[:, None]
                kpos = (j * bk + jnp.arange(bk))[None, :]
                mask = jnp.ones((bq, bk), bool)
                if causal:
                    mask = mask & (kpos <= qpos)
                if window is not None:
                    mask = mask & (kpos > qpos - window)
                logits = jnp.where(mask, logits, -1e30)
                mc = jnp.max(logits, axis=-1)
                mn = jnp.maximum(m, mc)
                p = jnp.exp(logits - mn[..., None])
                corr = jnp.exp(m - mn)
                ln = l * corr + jnp.sum(p, axis=-1)
                an = acc * corr[..., None] + jnp.einsum(
                    "bngqk,bnkv->bngqv", p, vj.astype(jnp.float32))
                return mn, ln, an

            # block-skip: chunk entirely above the diagonal / outside window
            needed = jnp.array(True)
            if causal:
                needed = needed & (j * bk <= i * bq + off + bq - 1)
            if window is not None:
                needed = needed & ((j + 1) * bk - 1 > i * bq + off - window)
            m, l, acc = jax.lax.cond(needed, compute, skip, (m, l, acc))
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.astype(q.dtype)

    _, oc = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    # (nq, B, Hkv, g, bq, dv) -> (B, T, H, dv)
    return oc.transpose(1, 0, 4, 2, 3, 5).reshape(B, T, H, dv)
