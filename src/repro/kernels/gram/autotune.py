"""Analytic (bf, bn) tile autotuner for the gram / gram_cross kernels.

The streaming gram kernel (``repro.kernels.gram.gram``) tiles its grid as
``(F/bf, F/bf, N/bn)`` with the token dimension innermost: every X tile is
read once per output block *row/column*, so total HBM reads are

    bytes_in(bf) = 2 * Np * Fp * (Fp / bf) * itemsize

— larger ``bf`` means fewer passes over X, smaller ``bf`` means less VMEM.
FLOPs are fixed at ``2 * Np * Fp^2`` (Np/Fp = zero-padded dims). This module
picks the (bf, bn) pair minimising the roofline time

    t(bf, bn) = max(flops / peak_flops, bytes / hbm_bw)

over a candidate grid, subject to the VMEM budget (double-buffered input
tiles + fp32 accumulator scratch + output block) and TPU tiling constraints
(lane dim multiple of 128, sublane multiple of 8 fp32 / 16 bf16). Hardware
constants come from ``repro.roofline.analysis.HW`` — the same numbers the
dry-run roofline uses, so kernel tunings and model-level rooflines agree
(see docs/roofline.md).

Choices are cached per (N, F, dtype, budget) — the calibration hot loop
re-resolves tiles every batch, and calibration streams have constant
shapes. Because the fixed legacy default (128, 512) is always in the
candidate set, the autotuned pick is never *predicted* slower than it
(gated in benchmarks/bench_calibration.py).

Examples (doctested in CI):

>>> choose_tiles(8192, 4096)                     # big square: go wide
(512, 1024)
>>> choose_tiles(8192, 4096, "bfloat16")         # bf16 halves input traffic
(512, 2048)
>>> choose_tiles(300, 100)                       # ragged small shape: the
(128, 512)
>>> # clamp bn = min(bn, N) = 300 makes deeper tiles pure padding waste
>>> choose_tiles(8192, 4096) is choose_tiles(8192, 4096)   # cached
True
>>> t_auto = predicted_time(8192, 4096, "float32", *choose_tiles(8192, 4096))
>>> t_auto <= predicted_time(8192, 4096, "float32", 128, 512)
True

Run ``python -m repro.kernels.gram.autotune`` for the tuning table over the
canonical calibration shapes.
"""
from __future__ import annotations

import functools
from typing import Iterable, List, Optional, Tuple

import jax.numpy as jnp

from repro.roofline.analysis import HW

# candidate tile grid: bf (feature block) on the 128-lane register width,
# bn (token block) on the fp32/bf16 sublane multiples. (128, 512) — the
# legacy fixed default — must stay in this set so autotuned picks are never
# predicted slower than it.
BF_CANDIDATES = (128, 256, 512)
BN_CANDIDATES = (256, 512, 1024, 2048)

#: VMEM budget for one kernel instance. Physical VMEM is ~16 MiB/core; the
#: margin leaves room for the compiler's own spills and semaphores.
DEFAULT_VMEM_BUDGET = 12 * 2 ** 20

#: fixed cost per grid cell (dispatch + pipeline bubble + accumulator
#: revisit). Total HBM traffic is independent of bn (the fp32 accumulator
#: stays VMEM-resident across the token grid), so this term is what makes
#: deeper token tiles win once VMEM allows them.
CELL_OVERHEAD_S = 5e-7

_LANE = 128
_SUBLANE = {2: 16, 4: 8}        # itemsize -> min sublane multiple


def _round_up(n: int, b: int) -> int:
    return -(-n // b) * b


def _itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _eff(n: int, f: int, bf: int, bn: int) -> Tuple[int, int, int, int]:
    """Effective (clamped) tiles + padded dims, mirroring ``gram.gram``'s
    ``bf = min(bf, F)`` clamp and zero-padding."""
    bf_e, bn_e = min(bf, f), min(bn, n)
    return bf_e, bn_e, _round_up(f, bf_e), _round_up(n, bn_e)


def vmem_bytes(bf: int, bn: int, dtype="float32") -> int:
    """VMEM footprint of one (bf, bn) kernel instance, in bytes.

    Two input tiles (xi, xj) of (bn, bf) in the streaming dtype, double
    buffered by the pipeline; fp32 accumulator scratch (bf, bf) + column-sum
    row; fp32 output block (bf, bf) + (1, bf).

    >>> vmem_bytes(128, 512) == 2 * 2 * 512 * 128 * 4 + 2 * (128 * 128 + 128) * 4
    True
    """
    el = _itemsize(dtype)
    inputs = 2 * 2 * bn * bf * el              # xi + xj, double buffered
    scratch = (bf * bf + bf) * 4               # fp32 accumulator + colsum
    out = (bf * bf + bf) * 4                   # fp32 output block + s1 row
    return inputs + scratch + out


def predicted_time(n: int, f: int, dtype, bf: int, bn: int,
                   hw: HW = HW()) -> float:
    """Roofline-model seconds for one full (N, F) gram at tiles (bf, bn).

    Memory term: every X tile is read once per output block row/column
    (2 * Np * Fp * (Fp/bf) * itemsize input bytes) plus the fp32 output
    write. Compute term: 2 * Np * Fp^2 MACs-as-flops on the MXU; fp32
    inputs run the MXU at half its bf16 rate. A fixed ``CELL_OVERHEAD_S``
    per grid cell rewards deeper tiles. Padding waste (Np, Fp) is charged
    to every term, which is what steers ragged shapes to small tiles.
    """
    el = _itemsize(dtype)
    bf_e, bn_e, fp, np_ = _eff(n, f, bf, bn)
    flops = 2.0 * np_ * fp * fp
    bytes_in = 2.0 * np_ * fp * (fp / bf_e) * el
    bytes_out = (fp * fp + fp) * 4.0
    peak = hw.peak_flops * (2.0 / max(2, el))   # fp32 MXU ~ half bf16 rate
    cells = (fp // bf_e) ** 2 * (np_ // bn_e)
    return max(flops / peak, (bytes_in + bytes_out) / hw.hbm_bw) \
        + cells * CELL_OVERHEAD_S


@functools.lru_cache(maxsize=4096)
def choose_tiles(n: int, f: int, dtype: str = "float32", *,
                 vmem_budget: int = DEFAULT_VMEM_BUDGET,
                 hw: HW = HW()) -> Tuple[int, int]:
    """Pick (bf, bn) for an (N, F) gram: argmin of ``predicted_time`` over
    the candidate grid, subject to ``vmem_bytes <= vmem_budget`` and the
    dtype's tiling constraints. Cached per (N, F, dtype, budget).

    The returned tiles may exceed N/F for small inputs — ``gram.gram``
    clamps with ``min(bf, F)`` / ``min(bn, N)`` and zero-pads, so any
    choice from the candidate grid is shape-safe.

    >>> bf, bn = choose_tiles(4096, 192)        # DeiT-tiny width
    >>> bf % 128 == 0 and bn % 8 == 0
    True
    >>> vmem_bytes(*choose_tiles(100_000, 8192)) <= DEFAULT_VMEM_BUDGET
    True
    """
    el = _itemsize(dtype)
    sub = _SUBLANE.get(el, 8)
    feasible = []
    for bf in BF_CANDIDATES:
        for bn in BN_CANDIDATES:
            if bf % _LANE or bn % sub:
                continue
            if vmem_bytes(bf, bn, dtype) > vmem_budget:
                continue
            feasible.append((predicted_time(n, f, dtype, bf, bn, hw),
                             bf, bn))
    assert feasible, (n, f, dtype, vmem_budget)
    # stable tie-break: prefer smaller VMEM footprint, then the legacy
    # default ordering (bf asc, bn asc) so equal-cost picks are deterministic
    feasible.sort(key=lambda t: (t[0], vmem_bytes(t[1], t[2], dtype),
                                 t[1], t[2]))
    _, bf, bn = feasible[0]
    return bf, bn


# ---------------------------------------------------------------------------
# tuning table (the kernel-side roofline record, see docs/roofline.md)
# ---------------------------------------------------------------------------

#: canonical calibration shapes: (tokens N, width F) for DeiT-Ti/-B/-H MLP
#: hiddens, an LM d_ff, and a ragged zero-padded case.
DEFAULT_SHAPES = ((4096, 192), (4096, 768), (25088, 1280), (16384, 3072),
                  (8192, 12800), (300, 100))


def tuning_table(shapes: Optional[Iterable[Tuple[int, int]]] = None,
                 dtypes: Tuple[str, ...] = ("float32", "bfloat16"),
                 hw: HW = HW()) -> List[dict]:
    """Rows of {n, f, dtype, bf, bn, t_pred, t_fixed, speedup, vmem_kb} for
    each (shape, dtype) — the per-kernel counterpart of the dry-run
    roofline tables (docs/roofline.md)."""
    rows = []
    for n, f in (shapes or DEFAULT_SHAPES):
        for dt in dtypes:
            bf, bn = choose_tiles(n, f, dt, hw=hw)
            t = predicted_time(n, f, dt, bf, bn, hw)
            t_fixed = predicted_time(n, f, dt, 128, 512, hw)
            rows.append({"n": n, "f": f, "dtype": dt, "bf": bf, "bn": bn,
                         "t_pred": t, "t_fixed": t_fixed,
                         "speedup": t_fixed / t,
                         "vmem_kb": vmem_bytes(bf, bn, dt) // 1024})
    return rows


def main() -> int:
    print("n,f,dtype,bf,bn,t_pred_us,t_fixed_us,speedup,vmem_kb")
    for r in tuning_table():
        print(f"{r['n']},{r['f']},{r['dtype']},{r['bf']},{r['bn']},"
              f"{r['t_pred']*1e6:.1f},{r['t_fixed']*1e6:.1f},"
              f"{r['speedup']:.2f}x,{r['vmem_kb']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
