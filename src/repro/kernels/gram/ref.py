"""Pure-jnp oracle for streaming second-moment accumulation."""
from __future__ import annotations

import jax.numpy as jnp


def gram(x):
    """x: (N, F) -> {'s2': (F, F) fp32 X^T X, 's1': (F,) column sums}."""
    xf = x.astype(jnp.float32)
    return {"s2": xf.T @ xf, "s1": jnp.sum(xf, axis=0)}


def gram_cross(x, y):
    """x: (N, Fx), y: (N, Fy) -> {'s2': (Fx, Fy) fp32 X^T Y, 's1': (Fy,)
    column sums of Y}. The rectangular gram a model-sharded calibration pass
    computes per shard: Y is the shard's local column block of X."""
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    return {"s2": xf.T @ yf, "s1": jnp.sum(yf, axis=0)}
