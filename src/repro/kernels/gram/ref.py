"""Pure-jnp oracle for streaming second-moment accumulation."""
from __future__ import annotations

import jax.numpy as jnp


def gram(x):
    """x: (N, F) -> {'s2': (F, F) fp32 X^T X, 's1': (F,) column sums}."""
    xf = x.astype(jnp.float32)
    return {"s2": xf.T @ xf, "s1": jnp.sum(xf, axis=0)}
