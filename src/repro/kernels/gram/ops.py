"""Public gram op with backend dispatch (env ``REPRO_GRAM_IMPL`` overrides)."""
from __future__ import annotations

import os

import jax

from repro.kernels.gram import ref as _ref
from repro.kernels.gram.gram import gram as _pallas_gram


def _resolve_impl(N: int, F: int) -> str:
    impl = os.environ.get("REPRO_GRAM_IMPL", "")
    if impl:
        return impl
    if jax.default_backend() == "tpu" and N % 512 == 0 and F % 128 == 0:
        return "pallas"
    return "ref"


def gram(x, impl=None):
    """x: (N, F) -> {'s2': (F, F), 's1': (F,)} in fp32."""
    N, F = x.shape
    impl = impl or _resolve_impl(N, F)
    if impl == "ref":
        return _ref.gram(x)
    bn = 512 if N % 512 == 0 else N
    bf = 128 if F % 128 == 0 else F
    return _pallas_gram(x, bf=bf, bn=bn, interpret=(impl == "interpret"))
