"""Public gram ops with backend dispatch (env ``REPRO_GRAM_IMPL`` overrides).

Dispatch policy (the calibration hot path calls this for every second-moment
reduction, see ``repro.core.stats._moments``):

  * TPU backend  -> the Pallas streaming kernel; arbitrary (N, F) shapes are
    handled by zero-padding inside ``gram.gram``.
  * anything else (CPU/GPU) -> the pure-jnp reference — XLA's plain matmul
    is the right lowering there, and it keeps interpret-mode Pallas off the
    production path.
  * ``REPRO_GRAM_IMPL`` in {"ref", "pallas", "interpret"} forces a backend
    (interpret = Pallas interpreter, used by the CPU test suite).

Tile sizes: ``bf``/``bn`` default to None = the analytic roofline autotuner
(``repro.kernels.gram.autotune``, cached per shape/dtype); pass ints to pin,
or set ``REPRO_GRAM_TILES=BF,BN`` to pin globally (what ``--gram-tiles`` in
launch.prune sets). Inputs stream in their own dtype — pass bf16 activations
to halve HBM traffic; accumulation is fp32 in all backends.

Three entry points:

  ``gram(x)``                 full (F, F) second moment of one host's X.
  ``gram_cross(x, y)``        rectangular X^T Y — the per-shard slab.
  ``gram_sharded(x, mesh)``   shard_map-routed gram whose (F, F) output is
                              column-sharded over the mesh's model axis; each
                              shard runs the kernel on its LOCAL (N_local,
                              F/m) column tile (zero-padding included), so no
                              device ever materialises — or pads — a full
                              Sigma. Batch-axis contributions are psum-reduced
                              inside the shard_map. Tiles autotune on the
                              local shapes.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.gram import ref as _ref
from repro.kernels.gram.gram import gram as _pallas_gram
from repro.kernels.gram.gram import gram_cross as _pallas_gram_cross


def _resolve_impl() -> str:
    impl = os.environ.get("REPRO_GRAM_IMPL", "")
    if impl:
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _env_tiles(bf, bn):
    """Apply the ``REPRO_GRAM_TILES=BF,BN`` global pin to unset tile args
    (explicit arguments win; unset with no env falls through to the
    autotuner inside the kernel)."""
    env = os.environ.get("REPRO_GRAM_TILES", "")
    if env:
        ebf, ebn = (int(v) for v in env.split(","))
        bf, bn = bf or ebf, bn or ebn
    return bf, bn


def gram(x, impl=None, *, bf=None, bn=None):
    """x: (N, F) -> {'s2': (F, F), 's1': (F,)} in fp32. Any (N, F), any
    float dtype (bf16 tiles stream at half the HBM traffic)."""
    impl = impl or _resolve_impl()
    if impl == "ref":
        return _ref.gram(x)
    bf, bn = _env_tiles(bf, bn)
    return _pallas_gram(x, bf=bf, bn=bn, interpret=(impl == "interpret"))


def gram_cross(x, y, impl=None, *, bf=None, bn=None):
    """x: (N, Fx), y: (N, Fy) -> {'s2': (Fx, Fy) X^T Y, 's1': (Fy,) column
    sums of Y} in fp32. The building block of the sharded gram: y is one
    shard's local column block of x."""
    impl = impl or _resolve_impl()
    if impl == "ref":
        return _ref.gram_cross(x, y)
    bf, bn = _env_tiles(bf, bn)
    return _pallas_gram_cross(x, y, bf=bf, bn=bn,
                              interpret=(impl == "interpret"))


def gram_sharded(x, mesh, *, model_axis="model", batch_axes=("data",),
                 impl=None, bf=None, bn=None):
    """Model-sharded gram: x (..., N, F) -> column-sharded {'s2', 's1'}.

    Args:
      x: (..., N, F) activations, any float dtype — bf16 streams each
        shard's tiles at half the HBM traffic (accumulation stays fp32
        inside the kernel). Leading dims (e.g. a scanned layer stack) are
        vmapped; N (tokens) must be divisible by the product of the mesh
        ``batch_axes`` sizes and F by the ``model_axis`` size.
      mesh: the ``jax.sharding.Mesh`` to shard over.
      model_axis: mesh axis name that partitions Sigma's columns.
      batch_axes: mesh axes the token rows are sharded over; their partial
        sums are psum-reduced inside the shard_map.
      bf, bn: kernel tiles; None = autotune on each shard's LOCAL
        (N_local, F/m) tile shape.

    Returns:
      {'s2': (..., F, F) fp32 with spec P(..., None, model_axis),
       's1': (..., F)  fp32 with spec P(..., model_axis)}.

    Each shard slices its own F/m column block and runs ``gram_cross`` on
    the local (N_local, F/m) tile — kernel zero-padding therefore happens on
    local tiles, and per-device Sigma memory is F*F/m, never F*F.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in batch_axes if a in sizes)
    m = sizes.get(model_axis, 1)
    d = int(np.prod([sizes[a] for a in batch_axes])) if batch_axes else 1
    lead = x.ndim - 2
    N, F = x.shape[-2], x.shape[-1]
    assert m > 1, "gram_sharded needs a >1-way model axis; use gram()"
    assert F % m == 0, f"F={F} not divisible by {model_axis}={m}"
    assert N % d == 0, f"N={N} not divisible by batch axes {batch_axes}={d}"
    fl = F // m
    row_spec = batch_axes if len(batch_axes) > 1 else \
        (batch_axes[0] if batch_axes else None)
    lead_spec = (None,) * lead

    def local(xl):
        # keep the streaming dtype: the kernel casts tiles to fp32 in VMEM,
        # so a bf16 xl halves this shard's HBM reads
        j = jax.lax.axis_index(model_axis)
        xj = jax.lax.dynamic_slice_in_dim(xl, j * fl, fl, axis=xl.ndim - 1)

        fn = lambda a, b: gram_cross(a, b, impl=impl, bf=bf, bn=bn)
        for _ in range(lead):
            fn = jax.vmap(fn)
        out = fn(xl, xj)
        if batch_axes:
            out = jax.lax.psum(out, batch_axes)
        return out

    return shard_map(
        local, mesh=mesh,
        in_specs=P(*lead_spec, row_spec, None),
        out_specs={"s2": P(*lead_spec, None, model_axis),
                   "s1": P(*lead_spec, model_axis)},
        check_rep=False)(x)
