"""Public gram op with backend dispatch (env ``REPRO_GRAM_IMPL`` overrides).

Dispatch policy (the calibration hot path calls this for every second-moment
reduction, see ``repro.core.stats._moments``):

  * TPU backend  -> the Pallas streaming kernel; arbitrary (N, F) shapes are
    handled by zero-padding inside ``gram.gram``.
  * anything else (CPU/GPU) -> the pure-jnp reference — XLA's plain matmul
    is the right lowering there, and it keeps interpret-mode Pallas off the
    production path.
  * ``REPRO_GRAM_IMPL`` in {"ref", "pallas", "interpret"} forces a backend
    (interpret = Pallas interpreter, used by the CPU test suite).
"""
from __future__ import annotations

import os

import jax

from repro.kernels.gram import ref as _ref
from repro.kernels.gram.gram import gram as _pallas_gram


def _resolve_impl() -> str:
    impl = os.environ.get("REPRO_GRAM_IMPL", "")
    if impl:
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def gram(x, impl=None, *, bf=128, bn=512):
    """x: (N, F) -> {'s2': (F, F), 's1': (F,)} in fp32. Any (N, F)."""
    impl = impl or _resolve_impl()
    if impl == "ref":
        return _ref.gram(x)
    return _pallas_gram(x, bf=bf, bn=bn, interpret=(impl == "interpret"))
