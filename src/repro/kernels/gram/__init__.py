from repro.kernels.gram import ops, ref
from repro.kernels.gram.ops import gram

__all__ = ["ops", "ref", "gram"]
