"""Pallas TPU kernel: streaming second-moment (X^T X + column-sum)
accumulation — the CORP calibration statistics hot-spot (Alg. 3 inputs).

The token dimension N streams through VMEM in (bn, bf) tiles; the (bf, bf)
fp32 accumulator lives in VMEM scratch across the token grid dimension, so
each X tile is read from HBM exactly once per output block row/column —
arithmetic intensity bf/itemsize flops per input byte on the MXU (bf = 128
fp32 is compute bound at 197 TFLOP/s / 819 GB/s; bn only amortises
per-grid-cell overhead, the accumulator never leaves VMEM). X tiles stream
in their input dtype — feeding bf16 halves HBM traffic while the VMEM
accumulator stays fp32 (the kernel casts per tile, which the MXU does
in-flight).

Tile sizes (bf, bn) default to the analytic roofline autotuner in
``repro.kernels.gram.autotune`` (pass them explicitly to pin); the full
derivation is in docs/kernels.md.

grid = (F/bf, F/bf, N/bn)   [token dim innermost]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gram import autotune


def _resolve_tiles(n, f, dtype, bf, bn):
    """Fill unset tile sizes from the autotuner (cached per shape/dtype)."""
    if bf is None or bn is None:
        abf, abn = autotune.choose_tiles(int(n), int(f),
                                         str(jnp.dtype(dtype)))
        bf, bn = bf or abf, bn or abn
    return bf, bn


def _gram_kernel(xi_ref, xj_ref, s2_ref, s1_ref, acc_ref, col_ref, *, nn):
    n = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        col_ref[...] = jnp.zeros_like(col_ref)

    xi = xi_ref[...].astype(jnp.float32)    # (bn, bf)
    xj = xj_ref[...].astype(jnp.float32)    # (bn, bf)
    acc_ref[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _colsum():
        col_ref[...] += jnp.sum(xi, axis=0, keepdims=True)

    @pl.when(n == nn - 1)
    def _finalize():
        s2_ref[...] = acc_ref[...]

        @pl.when(j == 0)
        def _w():
            s1_ref[...] = col_ref[...]


def _gram_cross_kernel(xi_ref, xj_ref, s2_ref, s1_ref, acc_ref, col_ref, *,
                       nn):
    """Rectangular variant: X^T Y with column sums of Y (the per-shard gram
    of a model-sharded calibration pass — Y is the local column block)."""
    n = pl.program_id(2)
    i = pl.program_id(0)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        col_ref[...] = jnp.zeros_like(col_ref)

    xi = xi_ref[...].astype(jnp.float32)    # (bn, bfx)
    xj = xj_ref[...].astype(jnp.float32)    # (bn, bfy)
    acc_ref[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _colsum():
        col_ref[...] += jnp.sum(xj, axis=0, keepdims=True)

    @pl.when(n == nn - 1)
    def _finalize():
        s2_ref[...] = acc_ref[...]

        @pl.when(i == 0)
        def _w():
            s1_ref[...] = col_ref[...]


# one rounding rule shared with the autotuner's padding model — the cost
# model is only valid while it mirrors the kernel's actual zero-padding
_round_up = autotune._round_up


@functools.partial(jax.jit, static_argnames=("bf", "bn", "interpret"))
def gram(x, *, bf=None, bn=None, interpret=False):
    """x: (N, F) -> {'s2': (F,F) fp32, 's1': (F,) fp32 column sums}.

    ``x`` may be any float dtype — tiles stream in that dtype and are cast
    to fp32 inside VMEM (bf16 input halves HBM traffic, the accumulator
    precision is unchanged). ``bf``/``bn`` default to the autotuned choice
    for (N, F, dtype); pass ints to pin.

    Arbitrary (N, F) are supported: inputs are zero-padded up to the block
    grid (zero rows/columns contribute nothing to either linear reduction)
    and the exact (F, F) / (F,) prefixes are sliced back out — so e.g.
    DeiT's F=192 hidden or an N that isn't a multiple of the token block
    never trips a divisibility assertion.
    """
    N, F = x.shape
    bf, bn = _resolve_tiles(N, F, x.dtype, bf, bn)
    bf = min(bf, F)
    bn = min(bn, N)
    Np, Fp = _round_up(N, bn), _round_up(F, bf)
    if (Np, Fp) != (N, F):
        x = jnp.pad(x, ((0, Np - N), (0, Fp - F)))
    nn = Np // bn
    kernel = functools.partial(_gram_kernel, nn=nn)
    s2, s1 = pl.pallas_call(
        kernel,
        grid=(Fp // bf, Fp // bf, nn),
        in_specs=[
            pl.BlockSpec((bn, bf), lambda i, j, n: (n, i)),
            pl.BlockSpec((bn, bf), lambda i, j, n: (n, j)),
        ],
        out_specs=[
            pl.BlockSpec((bf, bf), lambda i, j, n: (i, j)),
            pl.BlockSpec((1, bf), lambda i, j, n: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Fp, Fp), jnp.float32),
            jax.ShapeDtypeStruct((1, Fp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bf, bf), jnp.float32),
            pltpu.VMEM((1, bf), jnp.float32),
        ],
        interpret=interpret,
    )(x, x)
    return {"s2": s2[:F, :F], "s1": s1[0, :F]}


@functools.partial(jax.jit, static_argnames=("bf", "bn", "interpret"))
def gram_cross(x, y, *, bf=None, bn=None, interpret=False):
    """x: (N, Fx), y: (N, Fy) -> {'s2': (Fx, Fy) fp32 X^T Y, 's1': (Fy,)}.

    The sharded-calibration building block: each model shard owns a column
    block Y of the activation matrix and computes its (Fx, Fy) slab of the
    full gram plus Y's column sums. Tiles stream in the input dtype (fp32
    accumulator regardless) and default to the autotuned choice for the
    *local* (N, max(Fx, Fy)) shape — which is how the model-sharded path
    gets per-shard tile tuning for free. Zero-padding is applied
    independently to X and Y's local shapes — a shard never pads (or even
    sees) another shard's columns, which is what keeps per-shard VMEM
    traffic at ``Fx*Fy/m`` instead of ``Fx^2``.
    """
    N, Fx = x.shape
    Ny, Fy = y.shape
    assert N == Ny, (N, Ny)
    bf, bn = _resolve_tiles(N, max(Fx, Fy), x.dtype, bf, bn)
    bfx, bfy = min(bf, Fx), min(bf, Fy)
    bn = min(bn, N)
    Np = _round_up(N, bn)
    Fxp, Fyp = _round_up(Fx, bfx), _round_up(Fy, bfy)
    if (Np, Fxp) != (N, Fx):
        x = jnp.pad(x, ((0, Np - N), (0, Fxp - Fx)))
    if (Np, Fyp) != (N, Fy):
        y = jnp.pad(y, ((0, Np - N), (0, Fyp - Fy)))
    nn = Np // bn
    kernel = functools.partial(_gram_cross_kernel, nn=nn)
    s2, s1 = pl.pallas_call(
        kernel,
        grid=(Fxp // bfx, Fyp // bfy, nn),
        in_specs=[
            pl.BlockSpec((bn, bfx), lambda i, j, n: (n, i)),
            pl.BlockSpec((bn, bfy), lambda i, j, n: (n, j)),
        ],
        out_specs=[
            pl.BlockSpec((bfx, bfy), lambda i, j, n: (i, j)),
            pl.BlockSpec((1, bfy), lambda i, j, n: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Fxp, Fyp), jnp.float32),
            jax.ShapeDtypeStruct((1, Fyp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bfx, bfy), jnp.float32),
            pltpu.VMEM((1, bfy), jnp.float32),
        ],
        interpret=interpret,
    )(x, y)
    return {"s2": s2[:Fx, :Fy], "s1": s1[0, :Fy]}
