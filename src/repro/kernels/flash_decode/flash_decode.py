"""Pallas TPU flash-decoding kernel: split-KV single-token attention.

Decode attention is memory-bound (every step streams the whole KV cache),
so the kernel's job is to read each cache block from HBM exactly once at
full bandwidth while parallelizing over the sequence axis (one q token
gives no q-parallelism — FlashDecoding's split-K trick):

  phase 1 (this kernel): grid (B, Hkv, S/bs) — each program reduces one KV
    block to a partial (acc, m, l) triple for all g = H/Hkv query heads of
    its kv head, written per split.
  phase 2 (tiny jnp epilogue in ops.py): logsumexp-merge the S/bs partials.

VMEM per program: the (bs, d) K/V tiles + (g, dv) accumulators — the cache
never lands in VMEM twice, and splits proceed in parallel across the
sequence (unlike the fwd flash kernel's sequential kv grid walk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, acc_ref, m_ref, l_ref, *,
                   scale, g, bs):
    q = q_ref[0, 0].astype(jnp.float32)            # (g, dq)
    k = k_ref[0, 0].astype(jnp.float32)            # (bs, dq)
    v = v_ref[0, 0].astype(jnp.float32)            # (bs, dv)
    valid = valid_ref[0] != 0                      # (bs,)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (g, bs)
    logits = jnp.where(valid[None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)      # (g, 1)
    p = jnp.exp(logits - m)
    p = jnp.where(valid[None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc_ref[0, 0, 0] = acc                           # (g, dv)
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l


@functools.partial(jax.jit, static_argnames=("scale", "bs", "interpret"))
def decode_attention_splits(q, k, v, valid, *, scale, bs=512,
                            interpret=False):
    """q: (B,H,dq); k/v: (B,S,Hkv,d); valid: (B,S) int8/bool.

    Returns per-split partials (acc (B,Hkv,ns,g,dv), m, l (B,Hkv,ns,g,1)).
    """
    B, H, dq = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = H // Hkv
    bs = min(bs, S)
    assert S % bs == 0
    ns = S // bs

    qg = q.reshape(B, Hkv, g, dq)
    kt = k.transpose(0, 2, 1, 3)                     # (B,Hkv,S,dq)
    vt = v.transpose(0, 2, 1, 3)
    kernel = functools.partial(_decode_kernel, scale=scale, g=g, bs=bs)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=(B, Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, dq), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, dq), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, dv), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g, dv), lambda b, h, s: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, 1), lambda b, h, s: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, 1), lambda b, h, s: (b, h, s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, ns, g, dv), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, ns, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, ns, g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, valid.astype(jnp.int8))
    return acc, m, l
