"""Public flash-decoding op: split-KV kernel + logsumexp merge epilogue.

impl resolution (env ``REPRO_DECODE_IMPL`` overrides): 'pallas' on TPU,
'ref' elsewhere, 'interpret' for kernel tests.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode import ref as _ref
from repro.kernels.flash_decode.flash_decode import decode_attention_splits


def _resolve_impl(S: int, bs: int) -> str:
    impl = os.environ.get("REPRO_DECODE_IMPL", "")
    if impl:
        return impl
    if jax.default_backend() == "tpu" and S % bs == 0 and S >= 2 * bs:
        return "pallas"
    return "ref"


def _merge(acc, m, l):
    """Logsumexp-merge per-split partials over the split axis (ns)."""
    m_max = jnp.max(m, axis=2, keepdims=True)                # (B,Hkv,1,g,1)
    corr = jnp.exp(m - m_max)
    l_tot = jnp.sum(l * corr, axis=2)                        # (B,Hkv,g,1)
    acc_tot = jnp.sum(acc * corr, axis=2)                    # (B,Hkv,g,dv)
    return acc_tot / jnp.maximum(l_tot, 1e-30)


def decode_attention(q, k, v, valid, *, scale=None, bs=512, impl=None):
    """q: (B,H,dq); k/v: (B,S,Hkv,d); valid: (B,S) -> (B,H,dv)."""
    B, H, dq = q.shape
    S = k.shape[1]
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))
    impl = impl or _resolve_impl(S, bs)
    if impl == "ref":
        return _ref.decode_attention(q, k, v, valid, scale)
    acc, m, l = decode_attention_splits(q, k, v, valid, scale=scale,
                                        bs=min(bs, S),
                                        interpret=(impl == "interpret"))
    o = _merge(acc, m, l)                                    # (B,Hkv,g,dv)
    return o.reshape(B, H, -1).astype(q.dtype)
