"""Pure-jnp oracle for single-token decode attention (GQA, masked cache)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention(q, k, v, valid, scale: float):
    """q: (B,H,dq); k/v: (B,S,Hkv,d); valid: (B,S) -> (B,H,dv). fp32 math."""
    B, H, dq = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, dq).astype(jnp.float32)
    logits = jnp.einsum("bngq,bsnq->bngs", qg,
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bngs,bsnv->bngv", w, v.astype(jnp.float32))
    return o.reshape(B, H, -1).astype(q.dtype)
