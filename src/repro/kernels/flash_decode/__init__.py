from repro.kernels.flash_decode import ops, ref
from repro.kernels.flash_decode.ops import decode_attention

__all__ = ["ops", "ref", "decode_attention"]
