from repro.kernels.wkv6 import ops, ref
from repro.kernels.wkv6.ops import wkv6

__all__ = ["ops", "ref", "wkv6"]
