"""Pure-jnp oracle for the RWKV-6 (Finch) recurrence.

Per head with key/value dim N and state S in R^{N x N}:
    y_t = r_t^T (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with data-dependent per-channel decay w_t in (0, 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6(r, k, v, w, u, state=None):
    """r,k,v,w: (B, T, H, N); u: (H, N). Returns (y (B,T,H,N), final state).

    ``state``: optional (B, H, N, N) initial state (decode continuation).
    All math in fp32.
    """
    B, T, H, N = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S, rkvw):
        rt, kt, vt, wt = rkvw                    # (B, H, N)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, N, N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, S + uf[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, wf))
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), final
