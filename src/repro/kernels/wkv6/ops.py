"""Public RWKV-6 recurrence op with backend dispatch.

impl resolution (env ``REPRO_WKV_IMPL`` overrides):
  * 'pallas'   : chunked Pallas TPU kernel (forward).
  * 'xla'      : chunked jnp implementation mirroring the kernel math
                 (lax.scan over chunks) — matmul-heavy, differentiable,
                 used for CPU/GPU and all dry-run lowering.
  * 'ref'      : exact sequential scan oracle (small shapes).
  * 'interpret': Pallas kernel under interpret=True (kernel tests).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.wkv6 import ref as _ref
from repro.kernels.wkv6.wkv6 import wkv6 as _pallas_wkv6


def _resolve_impl(T: int, chunk: int) -> str:
    impl = os.environ.get("REPRO_WKV_IMPL", "")
    if impl:
        return impl
    if jax.default_backend() == "tpu":
        return "pallas"
    if T <= 2 * chunk or T % chunk:
        return "ref"
    return "xla"


def wkv6(r, k, v, w, u, state=None, *, chunk=64, impl=None):
    """r,k,v,w: (B,T,H,N); u: (H,N) -> (y, final_state (B,H,N,N))."""
    T = r.shape[1]
    impl = impl or _resolve_impl(T, chunk)
    if impl == "ref":
        return _ref.wkv6(r, k, v, w, u, state)
    if impl in ("pallas", "interpret"):
        return _pallas_wkv6(r, k, v, w, u, state, chunk=min(chunk, T),
                            interpret=(impl == "interpret"))
    return _chunked(r, k, v, w, u, state, chunk=chunk)


def _chunked(r, k, v, w, u, state, *, chunk):
    """Chunked jnp mirror of the Pallas kernel (stable, differentiable)."""
    B, T, H, N = r.shape
    L = chunk
    nc = T // L
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)
    uf = u.astype(jnp.float32)

    # (nc, B, H, L, N)
    def cm(x):
        return x.reshape(B, nc, L, H, N).transpose(1, 0, 3, 2, 4).astype(jnp.float32)

    rc, kc, vc, wc = cm(r), cm(k), cm(v), cm(w)

    def step(S, xs):
        rb, kb, vb, wb = xs                      # (B, H, L, N)
        lw = jnp.log(jnp.maximum(wb, 1e-38))
        cum = jnp.cumsum(lw, axis=2)
        cum_prev = cum - lw
        q = rb * jnp.exp(cum_prev)
        y_inter = jnp.einsum("bhln,bhnm->bhlm", q, S)
        dec = jnp.exp(cum_prev[:, :, :, None, :] - cum[:, :, None, :, :])
        att = jnp.einsum("bhin,bhjn,bhijn->bhij", rb, kb, dec)
        ii = jnp.arange(L)[:, None]
        jj = jnp.arange(L)[None, :]
        att = jnp.where(jj < ii, att, 0.0)
        diag = jnp.einsum("bhln,hn->bhl", rb * kb, uf)
        y_intra = jnp.einsum("bhij,bhjm->bhim", att, vb) \
            + diag[..., None] * vb
        cl = cum[:, :, L - 1]
        ke = kb * jnp.exp(cl[:, :, None, :] - cum)
        S = jnp.exp(cl)[..., None] * S + jnp.einsum("bhln,bhlm->bhnm", ke, vb)
        return S, (y_inter + y_intra)

    final, ys = jax.lax.scan(step, state, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, H, N).astype(r.dtype)
    return y, final
