"""Pallas TPU kernel for the RWKV-6 recurrence (chunked linear attention).

Chunked formulation per (batch, head, chunk) with the inter-chunk state S
carried in VMEM scratch across the chunk grid dimension:

  c_i   = cumsum_j<=i log w_j                      (per-channel, fp32)
  y_i   = (r_i * exp(c_{i-1}))^T S0                 [inter-chunk]
        + sum_{j<i} (sum_n r_in k_jn e^{c_{i-1,n}-c_{j,n}}) v_j
        + (r_i . (u*k_i)) v_i                       [intra-chunk]
  S'    = exp(c_L) * S0 + (k * exp(c_L - c))^T V    [state update]

All exponents are <= 0 (c is non-increasing), so the kernel is numerically
stable without clamping — this is why the intra-chunk attention uses an
explicit (L, L, N) per-channel decay tensor (1 MB VMEM at L=N=64) instead of
the overflow-prone exp(-c) factorization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sf_ref,
                 S_scr, *, L, N, nc):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        S_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)          # (L, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # (N,)
    S0 = S_scr[...]                              # (N, N)

    lw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(lw, axis=0)                 # (L, N) c_i
    cum_prev = cum - lw                          # c_{i-1}

    q = r * jnp.exp(cum_prev)                    # (L, N), exp <= 1... <= e^0
    y_inter = jax.lax.dot_general(
        q, S0, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # intra-chunk: att_ij = sum_n r_in k_jn exp(c_{i-1,n} - c_{j,n}), j < i
    dec = jnp.exp(cum_prev[:, None, :] - cum[None, :, :])   # (L, L, N)
    att = jnp.sum(r[:, None, :] * k[None, :, :] * dec, axis=-1)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    att = jnp.where(jj < ii, att, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)             # (L,)
    y_intra = jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + diag[:, None] * v

    y_ref[0, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update (exponents <= 0)
    cl = cum[L - 1]                                          # (N,)
    ke = k * jnp.exp(cl[None, :] - cum)                      # (L, N)
    S_new = jnp.exp(cl)[:, None] * S0 + jax.lax.dot_general(
        ke, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    S_scr[...] = S_new

    @pl.when(c == nc - 1)
    def _final():
        sf_ref[0, 0] = S_new.astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, state=None, *, chunk=64, interpret=False):
    """r,k,v,w: (B,T,H,N); u: (H,N); state: (B,H,N,N) or None.

    Returns (y (B,T,H,N), final_state (B,H,N,N)).
    """
    B, T, H, N = r.shape
    L = min(chunk, T)
    assert T % L == 0, "chunk must divide T"
    nc = T // L
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    rt, kt, vt, wt = (x.transpose(0, 2, 1, 3) for x in (r, k, v, w))
    kernel = functools.partial(_wkv6_kernel, L=L, N=N, nc=nc)
    y, sf = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, N), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, state)
    return y.transpose(0, 2, 1, 3), sf
