"""Atomic, restartable checkpointing.

Layout: <dir>/step_<n>/arrays.npz + manifest.json (tree structure + integrity
hash). Writes go to a tmp directory renamed into place (atomic on POSIX), so
a host dying mid-save can never produce a half-written "latest" checkpoint —
``latest_step`` skips incomplete/corrupt steps. ``AsyncCheckpointer``
serializes from host snapshots on a background thread so the train loop
never blocks on disk.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
           "int8", "uint64", "uint32", "uint16", "uint8", "bool",
           "complex64", "complex128"}

_RAW_VIEW = {2: np.uint16, 1: np.uint8, 4: np.uint32}


def _flatten(tree):
    """Flatten to {path: ndarray}; extended dtypes (bfloat16, fp8) stored as
    raw uint views with the true dtype recorded in the companion dict."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out, dtypes = {}, {}
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        a = np.asarray(leaf)
        if a.dtype.name not in _NATIVE:
            dtypes[path] = a.dtype.name
            a = a.view(_RAW_VIEW[a.dtype.itemsize])
        out[path] = a
    return out, dtypes


def _unflatten_into(tree, arrays, dtypes):
    import ml_dtypes
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        a = arrays[path]
        if path in dtypes:
            a = a.view(np.dtype(getattr(ml_dtypes, dtypes[path])))
        assert a.shape == leaf.shape, f"{path}: {a.shape} != {leaf.shape}"
        if a.dtype.name != str(np.dtype(leaf.dtype)):
            a = a.astype(leaf.dtype)
        leaves.append(a)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, extra: dict | None
                    = None) -> str:
    """Atomic save. Returns the final step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, dtypes = _flatten(jax.device_get(tree))
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **arrays)
    digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    manifest = {"step": step, "sha256": digest, "dtypes": dtypes,
                "n_arrays": len(arrays), "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _valid(step_dir: str) -> bool:
    man = os.path.join(step_dir, "manifest.json")
    npz = os.path.join(step_dir, "arrays.npz")
    if not (os.path.exists(man) and os.path.exists(npz)):
        return False
    try:
        m = json.load(open(man))
        digest = hashlib.sha256(open(npz, "rb").read()).hexdigest()
        return digest == m["sha256"]
    except Exception:
        return False


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest step with a *valid* checkpoint (corrupt/partial skipped)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                s = int(name.split("_")[1])
            except ValueError:
                continue
            if _valid(os.path.join(ckpt_dir, name)):
                steps.append(s)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any):
    """Restore into the structure (and dtypes) of ``like``."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(step_dir, "arrays.npz"))
    arrays = {k: data[k] for k in data.files}
    manifest = json.load(open(os.path.join(step_dir, "manifest.json")))
    return (_unflatten_into(like, arrays, manifest.get("dtypes", {})),
            manifest["extra"])


class AsyncCheckpointer:
    """Background-thread checkpointing from host snapshots."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error

    def save(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        snapshot = jax.device_get(tree)   # snapshot before returning

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, snapshot, extra)
                self._gc()
            except Exception as e:      # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
