"""repro: production-grade JAX framework implementing CORP
(Closed-Form One-shot Representation-Preserving Structured Pruning).

Layers:
  repro.kernels   - Pallas TPU kernels (flash attention, gram accumulation, wkv6)
  repro.models    - composable transformer model zoo (dense / GQA / MLA / MoE /
                    RWKV6 / Mamba-hybrid / enc-dec / ViT)
  repro.core      - the paper's contribution: distributed calibration statistics,
                    ranking, closed-form compensation, weight folding
  repro.data      - deterministic sharded synthetic data pipeline
  repro.optim     - AdamW + schedules (ZeRO-shardable state)
  repro.checkpoint- atomic async checkpointing / restart
  repro.distrib   - sharding rules, fault tolerance runtime
  repro.launch    - mesh, dry-run, train, serve, prune drivers
  repro.roofline  - roofline analysis from compiled artifacts
"""

__version__ = "1.0.0"
