from repro.models.api import Model, build_model, cache_specs, input_specs

__all__ = ["Model", "build_model", "cache_specs", "input_specs"]
