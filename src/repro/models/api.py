"""Public model API: build any assigned architecture from its config.

``build_model(cfg)`` returns a ``Model`` bundling pure functions:
  init(key)                          -> params
  apply(params, batch, taps=None)    -> model outputs (family-specific)
  loss(params, batch)                -> scalar loss (train objective)
  prefill(params, batch, max_len[, lengths]) -> (logits, cache)
                                        (lengths: ragged right-padded prompts)
  decode_step(params, token, cache)  -> (logits, cache)
  init_cache(batch, max_len)         -> empty cache (decode-only dry-runs)

``input_specs(cfg, shape)`` returns jax.ShapeDtypeStruct stand-ins for every
input of the step selected by the shape cell (train/prefill/decode) — used by
the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models import vit as vit_mod

# stub frontend sizes (assignment: modality frontends provide precomputed
# embeddings; these are the prepended lengths used by the shape cells)
VLM_PATCHES = 1024
ENC_MEMORY_FOR_DECODE = 4096


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    apply: Callable
    loss: Callable
    prefill: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    init_cache: Optional[Callable] = None


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "lm":
        def apply_fn(params, batch, taps=None, train=False):
            return lm_mod.apply_lm(params, batch["tokens"], cfg, taps=taps,
                                   patch_embeds=batch.get("patch_embeds"),
                                   train=train)

        def prefill_fn(params, batch, max_len, lengths=None):
            return lm_mod.lm_prefill(params, batch["tokens"], cfg, max_len,
                                     patch_embeds=batch.get("patch_embeds"),
                                     lengths=lengths)

        return Model(
            cfg=cfg,
            init=lambda key: lm_mod.init_lm(key, cfg),
            apply=apply_fn,
            loss=lambda params, batch, train=True:
                lm_mod.lm_loss(params, batch, cfg, train=train),
            prefill=prefill_fn,
            decode_step=lambda params, token, cache:
                lm_mod.lm_decode_step(params, token, cache, cfg),
            init_cache=lambda batch, max_len:
                lm_mod.init_lm_cache(cfg, batch, max_len),
        )
    if cfg.family == "vit":
        def v_apply(params, batch, taps=None, train=False):
            inputs = batch.get("images", batch.get("embeds"))
            return vit_mod.apply_vit(params, inputs, cfg, taps=taps,
                                     train=train)
        return Model(
            cfg=cfg,
            init=lambda key: vit_mod.init_vit(key, cfg),
            apply=v_apply,
            loss=lambda params, batch, train=True:
                vit_mod.vit_loss(params, batch, cfg, train=train),
        )
    if cfg.family == "encdec":
        def e_apply(params, batch, taps=None, train=False):
            return encdec_mod.apply_encdec(params, batch["frames"],
                                           batch["tokens"], cfg, taps=taps,
                                           train=train)
        return Model(
            cfg=cfg,
            init=lambda key: encdec_mod.init_encdec(key, cfg),
            apply=e_apply,
            loss=lambda params, batch, train=True:
                encdec_mod.encdec_loss(params, batch, cfg, train=train),
            prefill=lambda params, batch, max_len, lengths=None:
                encdec_mod.encdec_prefill(params, batch["frames"],
                                          batch["tokens"], cfg, max_len,
                                          lengths=lengths),
            decode_step=lambda params, token, cache:
                encdec_mod.encdec_decode_step(params, token, cache, cfg),
        )
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch specs for the step the shape cell lowers.

    train  -> the loss/train_step batch
    prefill-> the prefill batch
    decode -> {'token': (B,1)} (cache specs come from cache_specs())
    """
    B, T = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    if cfg.family == "lm":
        if shape.kind == "train":
            batch = {"tokens": _sds((B, T), jnp.int32),
                     "labels": _sds((B, T), jnp.int32)}
            if cfg.frontend == "patch_stub":
                tt = T - VLM_PATCHES
                batch = {"tokens": _sds((B, tt), jnp.int32),
                         "labels": _sds((B, tt), jnp.int32),
                         "patch_embeds": _sds((B, VLM_PATCHES, cfg.d_model), dt)}
            return batch
        if shape.kind == "prefill":
            batch = {"tokens": _sds((B, T), jnp.int32)}
            if cfg.frontend == "patch_stub":
                batch = {"tokens": _sds((B, T - VLM_PATCHES), jnp.int32),
                         "patch_embeds": _sds((B, VLM_PATCHES, cfg.d_model), dt)}
            return batch
        return {"token": _sds((B, 1), jnp.int32)}
    if cfg.family == "encdec":
        if shape.kind == "train":
            return {"frames": _sds((B, T, cfg.d_model), dt),
                    "tokens": _sds((B, T), jnp.int32),
                    "labels": _sds((B, T), jnp.int32)}
        if shape.kind == "prefill":
            return {"frames": _sds((B, T, cfg.d_model), dt),
                    "tokens": _sds((B, T), jnp.int32)}
        return {"token": _sds((B, 1), jnp.int32)}
    if cfg.family == "vit":
        N = vit_mod.num_patches(cfg)
        if cfg.frontend == "patch_conv":
            return {"images": _sds((B, cfg.img_size, cfg.img_size, 3),
                                   jnp.float32),
                    "labels": _sds((B,), jnp.int32)}
        return {"embeds": _sds((B, N, cfg.d_model), dt),
                "labels": _sds((B,), jnp.int32)}
    raise ValueError(cfg.family)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """ShapeDtypeStruct pytree for the decode cache at this shape cell."""
    assert shape.kind == "decode"
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "lm":
        return jax.eval_shape(
            lambda: lm_mod.init_lm_cache(cfg, B, S))
    if cfg.family == "encdec":
        model = build_model(cfg)
        params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        batch = {"frames": _sds((B, ENC_MEMORY_FOR_DECODE, cfg.d_model),
                                cfg.dtype),
                 "tokens": _sds((B, S), jnp.int32)}
        return jax.eval_shape(
            lambda p, fr, tk: model.prefill(
                p, {"frames": fr, "tokens": tk}, S)[1],
            params_sds, batch["frames"], batch["tokens"])
    raise ValueError(cfg.family)
