"""Decoder-only language model with scan-over-layers depth layout.

Depth is partitioned by ``cfg.layout()`` into scanned segments (stacked
params, one compile per repeating super-block) and unrolled remainder layers.
Supports the stub VLM frontend (precomputed patch embeddings prepended to the
token stream) per the assignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain
from repro.models import blocks as blk
from repro.models.common import dtype_of, embed_init, init_norm, apply_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

import os

_NESTED_REMAT = os.environ.get("REPRO_NESTED_REMAT", "0") == "1"


def _seg_name(si: int) -> str:
    return f"seg{si}"


def init_lm(key, cfg):
    ks = jax.random.split(key, 4 + cfg.n_layers)
    params = {"embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model),
                                  dtype_of(cfg)),
              "final_norm": init_norm(ks[1], cfg)}
    if not cfg.tie_embeddings:
        params["head"] = embed_init(ks[2], (cfg.d_model, cfg.padded_vocab),
                                    dtype_of(cfg))
    ki = 3
    for si, seg in enumerate(cfg.layout()):
        if seg[0] == "unroll":
            layers = {}
            for j, li in enumerate(seg[1]):
                kind, moe = cfg.layer_spec(li)
                dense_ff = cfg.eff_dense_d_ff if (cfg.moe is not None
                                                  and not moe
                                                  and cfg.dense_d_ff) else None
                layers[f"l{j}"] = blk.init_block(ks[ki + li], cfg, kind, moe,
                                                 dense_ff=dense_ff)
            params[_seg_name(si)] = layers
        else:
            _, reps, idxs = seg
            # stacked params: init each position once, tile via vmap over keys
            def init_pos(pos_key, li):
                kind, moe = cfg.layer_spec(li)
                return blk.init_block(pos_key, cfg, kind, moe)
            stacked = {}
            for j, li in enumerate(idxs):
                pos_keys = jax.random.split(
                    jax.random.fold_in(ks[ki], li), reps)
                stacked[f"p{j}"] = jax.vmap(
                    functools.partial(init_pos, li=li))(pos_keys)
            params[_seg_name(si)] = stacked
    return params


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill / calibration)
# ---------------------------------------------------------------------------

def _run_segments(params, x, cfg, *, positions, taps, train, mask_kind="causal",
                  mem=None, remat=False):
    aux_total = jnp.zeros((), jnp.float32)
    for si, seg in enumerate(cfg.layout()):
        name = _seg_name(si)
        if seg[0] == "unroll":
            for j, li in enumerate(seg[1]):
                kind, moe = cfg.layer_spec(li)
                t = {} if taps is not None else None
                x, aux = blk.apply_block(params[name][f"l{j}"], x, cfg, kind,
                                         moe, positions=positions, taps=t,
                                         mask_kind=mask_kind, mem=mem,
                                         train=train)
                x = constrain(x, "residual")
                aux_total = aux_total + aux
                if taps is not None:
                    for k, v in t.items():
                        taps[f"{name}/l{j}/{k}"] = v
        else:
            _, reps, idxs = seg
            specs = [cfg.layer_spec(li) for li in idxs]

            def one_layer(pj, x, positions, mem, *, kind, moe):
                y, aux = blk.apply_block(pj, x, cfg, kind, moe,
                                         positions=positions, taps=None,
                                         mask_kind=mask_kind, mem=mem,
                                         train=train)
                return constrain(y, "residual"), aux

            def body(carry, pslice):
                x = carry
                aux_g = jnp.zeros((), jnp.float32)
                ys = {}
                for j, (kind, moe) in enumerate(specs):
                    if taps is None and remat and _NESTED_REMAT:
                        # nested per-layer remat (§Perf iteration J2):
                        # REFUTED on the CPU-backend measurement — the
                        # backward transient did not shrink and recompute
                        # flops rose 19%; kept behind a flag for real-TPU
                        # re-evaluation (EXPERIMENTS.md §Perf).
                        fn = functools.partial(one_layer, kind=kind, moe=moe)
                        x, aux = jax.checkpoint(fn)(pslice[f"p{j}"], x,
                                                    positions, mem)
                        aux_g = aux_g + aux
                        continue
                    t = {} if taps is not None else None
                    x, aux = blk.apply_block(pslice[f"p{j}"], x, cfg, kind,
                                             moe, positions=positions, taps=t,
                                             mask_kind=mask_kind, mem=mem,
                                             train=train)
                    x = constrain(x, "residual")
                    aux_g = aux_g + aux
                    if taps is not None:
                        for k, v in t.items():
                            ys[f"p{j}/{k}"] = v
                return x, (aux_g, ys)

            if remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            x, (aux_g, ys) = jax.lax.scan(body, x, params[name])
            aux_total = aux_total + jnp.sum(aux_g)
            if taps is not None:
                for k, v in ys.items():
                    taps[f"{name}/{k}"] = v   # stacked (reps, ...)
    return x, aux_total


def apply_lm(params, tokens, cfg, *, taps=None, patch_embeds=None,
             train=False, remat=None):
    """tokens: (B, T) int32; patch_embeds: (B, P, D) optional (VLM stub).

    Returns (logits (B, T_total, padded_vocab), aux_loss).
    """
    x = params["embed"][tokens]
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = constrain(x, "residual")
    remat = train if remat is None else remat
    x, aux = _run_segments(params, x, cfg, positions=positions, taps=taps,
                           train=train, remat=remat)
    x = apply_norm(params["final_norm"], x, cfg)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = constrain(x @ head, "logits")
    return logits, aux


def lm_loss(params, batch, cfg, *, train=True):
    """batch: {'tokens': (B,T), 'labels': (B,T)} -> scalar loss (fp32)."""
    logits, aux = apply_lm(params, batch["tokens"], cfg,
                           patch_embeds=batch.get("patch_embeds"),
                           train=train)
    labels = batch["labels"]
    if "patch_embeds" in batch:
        logits = logits[:, batch["patch_embeds"].shape[1]:]
    lf = logits.astype(jnp.float32)
    # mask padded vocab entries
    if cfg.padded_vocab != cfg.vocab_size:
        neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30, jnp.float32)
        lf = jnp.concatenate(
            [lf[..., :cfg.vocab_size],
             jnp.broadcast_to(neg, lf.shape[:-1] + neg.shape)], axis=-1)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def init_lm_cache(cfg, batch: int, max_len: int):
    caches = {"pos": jnp.zeros((batch,), jnp.int32)}
    for si, seg in enumerate(cfg.layout()):
        name = _seg_name(si)
        if seg[0] == "unroll":
            caches[name] = {
                f"l{j}": blk.init_block_cache(cfg, cfg.layer_spec(li)[0],
                                              batch, max_len)
                for j, li in enumerate(seg[1])}
        else:
            _, reps, idxs = seg
            def tile(tree, reps=reps):
                return jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), tree)
            caches[name] = {
                f"p{j}": tile(blk.init_block_cache(cfg, cfg.layer_spec(li)[0],
                                                   batch, max_len))
                for j, li in enumerate(idxs)}
    return caches


def lm_decode_step(params, token, cache, cfg):
    """token: (B, 1) int32. Returns (logits (B,1,V), new_cache)."""
    x = params["embed"][token]
    new_cache = {"pos": cache["pos"] + 1}
    for si, seg in enumerate(cfg.layout()):
        name = _seg_name(si)
        if seg[0] == "unroll":
            nc = {}
            for j, li in enumerate(seg[1]):
                kind, moe = cfg.layer_spec(li)
                x, c = blk.decode_block(params[name][f"l{j}"], x,
                                        cache[name][f"l{j}"], cfg, kind, moe)
                nc[f"l{j}"] = c
            new_cache[name] = nc
        else:
            _, reps, idxs = seg
            specs = [cfg.layer_spec(li) for li in idxs]

            def body(carry, slices):
                x = carry
                pslice, cslice = slices
                ncs = {}
                for j, (kind, moe) in enumerate(specs):
                    x, c = blk.decode_block(pslice[f"p{j}"], x,
                                            cslice[f"p{j}"], cfg, kind, moe)
                    ncs[f"p{j}"] = c
                return x, ncs

            x, ncs = jax.lax.scan(body, x, (params[name], cache[name]))
            new_cache[name] = ncs
    x = apply_norm(params["final_norm"], x, cfg)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    return x @ head, new_cache


def lm_prefill(params, tokens, cfg, max_len: int, patch_embeds=None,
               lengths=None):
    """Prefill: full forward returning (last-token logits, populated cache).

    Implemented as full-sequence attention + cache writeback per layer; for
    the dry-run shapes this is the cheapest correct formulation (one pass).

    ``lengths`` (B,) int32 enables *ragged* prefill on right-padded token
    batches: logits are gathered at position ``lengths-1`` per sample and
    every cache ``pos`` is set to ``lengths``, so padded tail positions are
    never read back (causality keeps rows < lengths exact). Only valid for
    pure global-attention stacks — sliding-window ring buffers and recurrent
    (mamba/rwkv) states are contaminated by pad tokens.
    """
    if lengths is not None and set(cfg.layer_kinds) != {"attn"}:
        raise ValueError("ragged prefill (lengths=) requires a pure "
                         f"global-attention stack, got {set(cfg.layer_kinds)}")
    B, T = tokens.shape
    x = params["embed"][tokens]
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        T = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    cache = {"pos": jnp.full((B,), T, jnp.int32) if lengths is None
             else lengths.astype(jnp.int32)}
    # sequence-parallel residual (§Perf iteration D1): turns the row-parallel
    # output-projection all-reduces into reduce-scatter/all-gather pairs and
    # keeps every (B,T,D) buffer sequence-sharded
    x = constrain(x, "residual")

    def run_layer(p, x, kind, moe):
        h = apply_norm(p["ln1"], x, cfg)
        from repro.models import attention as attn_mod
        from repro.models import mlp as mlp_mod
        from repro.models import ssm as ssm_mod
        if kind in ("attn", "swa"):
            y, c = attn_mod.apply_attn(p["mixer"], h, cfg, kind,
                                       positions=positions, return_cache=True)
            if kind == "swa":
                c = _window_cache(c, cfg, max_len)
            else:
                c = _pad_cache(c, max_len)
        elif kind == "mamba":
            y, c = ssm_mod.apply_mamba(p["mixer"], h, cfg)
        else:
            y, c = ssm_mod.apply_rwkv_time(p["mixer"], h, cfg)
            c = {"time": c}
        x = x + y
        h = apply_norm(p["ln2"], x, cfg)
        if kind == "rwkv":
            y, cs = ssm_mod.apply_rwkv_channel(p["mlp"], h, cfg)
            c["channel"] = cs
        elif moe:
            y, _ = mlp_mod.apply_moe(p["mlp"], h, cfg)
        else:
            y = mlp_mod.apply_mlp(p["mlp"], h, cfg)
        return constrain(x + y, "residual"), c

    for si, seg in enumerate(cfg.layout()):
        name = _seg_name(si)
        if seg[0] == "unroll":
            cs = {}
            for j, li in enumerate(seg[1]):
                kind, moe = cfg.layer_spec(li)
                x, c = run_layer(params[name][f"l{j}"], x, kind, moe)
                cs[f"l{j}"] = c
            cache[name] = cs
        else:
            _, reps, idxs = seg
            specs = [cfg.layer_spec(li) for li in idxs]

            def body(carry, pslice):
                x = carry
                cs = {}
                for j, (kind, moe) in enumerate(specs):
                    x, c = run_layer(pslice[f"p{j}"], x, kind, moe)
                    cs[f"p{j}"] = c
                return x, cs

            x, cs = jax.lax.scan(body, x, params[name])
            cache[name] = cs
    if lengths is None:
        x_last = x[:, -1:]
    else:
        idx = (lengths.astype(jnp.int32) - 1)[:, None, None]
        x_last = jnp.take_along_axis(x, idx, axis=1)
        cache = override_cache_pos(cache, lengths)
    x = apply_norm(params["final_norm"], x_last, cfg)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    return x @ head, cache


def override_cache_pos(tree, lengths):
    """Set every ``pos`` leaf of a prefill cache to per-sample ``lengths``.

    Per-layer caches carry their own ``pos`` (the decode valid-length); for a
    ragged (right-padded) prefill they must all report the true length, not
    the padded one. Scanned-segment leaves are (reps, B) — broadcast covers
    both layouts.
    """
    if isinstance(tree, dict):
        return {k: (jnp.broadcast_to(lengths.astype(v.dtype), v.shape)
                    if k == "pos" else override_cache_pos(v, lengths))
                for k, v in tree.items()}
    return tree


def _pad_cache(c, max_len):
    """Right-pad a freshly built cache to max_len time slots."""
    out = dict(c)
    for key in ("k", "v", "ckv", "k_rope"):
        if key in c:
            T = c[key].shape[1]
            if T < max_len:
                pad = [(0, 0)] * c[key].ndim
                pad[1] = (0, max_len - T)
                out[key] = jnp.pad(c[key], pad)
    return out


def _window_cache(c, cfg, max_len):
    """Convert a full prefill cache into the ring-buffer window cache."""
    W = min(cfg.sliding_window, max_len)
    T = c["k"].shape[1]
    B = c["k"].shape[0]
    n = min(W, T)
    keep_k = c["k"][:, T - n:]
    keep_v = c["v"][:, T - n:]
    pos_vals = jnp.arange(T - n, T, dtype=jnp.int32)
    slots = jnp.mod(pos_vals, W)
    k_ring = jnp.zeros((B, W) + c["k"].shape[2:], c["k"].dtype)
    v_ring = jnp.zeros((B, W) + c["v"].shape[2:], c["v"].dtype)
    k_ring = k_ring.at[:, slots].set(keep_k)
    v_ring = v_ring.at[:, slots].set(keep_v)
    abs_ring = jnp.full((B, W), -1, jnp.int32)
    abs_ring = abs_ring.at[:, slots].set(
        jnp.broadcast_to(pos_vals[None], (B, n)))
    return {"k": k_ring, "v": v_ring, "pos": c["pos"], "abs_pos": abs_ring}
