"""Attention mixers: GQA (full / sliding-window), MLA (DeepSeek-V3), cross.

CORP integration
----------------
* taps: every attention layer emits post-rope per-head ``q`` (B,T,H,dq) and
  ``k`` (B,T,Hkv,dq) when taping — the bilinear logit statistics the paper's
  Alg. 4/5 need. For MLA the tap covers the *nope* block only (the rope block
  is position-structural and is never pruned, see DESIGN.md).
* pruned models: ``cfg.eff_qk < cfg.qk_full``. RoPE frequencies for the kept
  rotary pairs are stored as a per-head buffer ``rope_inv`` inside the params
  (frozen in the optimizer), because the kept pair set differs per layer/head.
* rope-aware compensation folds per-pair 2x2 rotation-scaling blocks into
  W_q/W_k (class-2 compensator); qk-norm archs fold per-pair positive scales
  into the norm scale vectors (class-3); no-rope archs use the paper's full
  SVD fold (class-1). See repro.core.solve.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distrib.sharding import constrain, constrain_qkv
from repro.kernels.flash_attention import ops as flash_ops
from repro.models.common import (apply_rope, dense_init, dtype_of,
                                 rms_head_norm, rope_freqs, tap)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attn(key, cfg, kind: str, cross: bool = False):
    """kind: 'attn' | 'swa'; cross=True for decoder cross-attention."""
    if cfg.mla is not None and not cross:
        return _init_mla(key, cfg)
    dt = dtype_of(cfg)
    D, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dq, dv = cfg.eff_qk, cfg.d_head
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (D, H, dq), dt),
        "wk": dense_init(ks[1], (D, Hkv, dq), dt),
        "wv": dense_init(ks[2], (D, Hkv, dv), dt),
        "wo": dense_init(ks[3], (H, dv, D), dt, scale=1.0 / np.sqrt(H * dv)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, dq), jnp.float32)
        p["bk"] = jnp.zeros((Hkv, dq), jnp.float32)
        p["bv"] = jnp.zeros((Hkv, dv), jnp.float32)
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((dq,), jnp.float32)
        p["k_scale"] = jnp.ones((dq,), jnp.float32)
    if _uses_rope(cfg):
        theta = cfg.rope_theta_local if kind == "swa" else cfg.rope_theta
        inv = jnp.asarray(rope_freqs(dq, theta), jnp.float32)
        # per-head copy so pruning can gather kept pair frequencies per head
        p["rope_inv_q"] = jnp.tile(inv[None, :], (H, 1))
        p["rope_inv_k"] = jnp.tile(inv[None, :], (Hkv, 1))
    return p


def _uses_rope(cfg) -> bool:
    return cfg.family == "lm" and cfg.rwkv is None


def _init_mla(key, cfg):
    dt = dtype_of(cfg)
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    nope = cfg.eff_qk           # prunable block
    ks = jax.random.split(key, 8)
    inv = jnp.asarray(rope_freqs(m.qk_rope_dim, cfg.rope_theta), jnp.float32)
    return {
        "w_dq": dense_init(ks[0], (D, m.q_lora_rank), dt),
        "q_norm_scale": jnp.ones((m.q_lora_rank,), jnp.float32),
        "w_uq_nope": dense_init(ks[1], (m.q_lora_rank, H, nope), dt),
        "w_uq_rope": dense_init(ks[2], (m.q_lora_rank, H, m.qk_rope_dim), dt),
        "w_dkv": dense_init(ks[3], (D, m.kv_lora_rank), dt),
        "w_k_rope": dense_init(ks[4], (D, m.qk_rope_dim), dt),
        "kv_norm_scale": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "w_uk_nope": dense_init(ks[5], (m.kv_lora_rank, H, nope), dt),
        "w_uv": dense_init(ks[6], (m.kv_lora_rank, H, m.v_dim), dt),
        "wo": dense_init(ks[7], (H, m.v_dim, D), dt,
                         scale=1.0 / np.sqrt(H * m.v_dim)),
        "rope_inv": inv,
    }


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------

def _project_qkv(p, x, cfg, positions, kind, taps):
    """Common Q/K/V projection + bias + qk-norm + rope + tap."""
    dt = x.dtype
    q = jnp.einsum("btd,dhq->bthq", x, p["wq"])
    k = jnp.einsum("btd,dhq->bthq", x, p["wk"])
    v = jnp.einsum("btd,dhv->bthv", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if "q_scale" in p:
        q = rms_head_norm(q, p["q_scale"], cfg.norm_eps)
        k = rms_head_norm(k, p["k_scale"], cfg.norm_eps)
    if "rope_inv_q" in p:
        q = _rope_gathered(q, positions, p["rope_inv_q"])
        k = _rope_gathered(k, positions, p["rope_inv_k"])
    q, k, v = constrain_qkv(q, k, v)
    tap(taps, "q", q)
    tap(taps, "k", k)
    return q, k, v


def _rope_gathered(x, positions, inv):
    """Rope with per-head frequency table inv: (H, D/2)."""
    ang = positions.astype(jnp.float32)[:, :, None, None] * inv  # (B,T,H,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


def apply_attn(p, x, cfg, kind, *, positions, taps=None, return_cache=False,
               mask_kind="causal"):
    """Full-sequence attention. x: (B, T, D).

    mask_kind: 'causal' | 'window' | 'full'. Returns (y, cache|None).
    """
    if cfg.mla is not None and "w_dq" in p:
        return _apply_mla(p, x, cfg, positions=positions, taps=taps,
                          return_cache=return_cache)
    q, k, v = _project_qkv(p, x, cfg, positions, kind, taps)
    window = cfg.sliding_window if (kind == "swa" and mask_kind != "full") else None
    scale = 1.0 / np.sqrt(cfg.qk_full if cfg.qk_kept is None else cfg.qk_full)
    o = flash_ops.attention(q, k, v, causal=(mask_kind != "full"),
                            window=window, scale=scale)
    y = jnp.einsum("bthv,hvd->btd", o, p["wo"])
    cache = None
    if return_cache:
        cache = {"k": k, "v": v,
                 "pos": jnp.full((x.shape[0],), x.shape[1], jnp.int32)}
    return y, cache


def _apply_mla(p, x, cfg, *, positions, taps=None, return_cache=False):
    m = cfg.mla
    B, T, D = x.shape
    cq = jnp.einsum("btd,dr->btr", x, p["w_dq"])
    cq = rms_head_norm(cq, p["q_norm_scale"], cfg.norm_eps)
    q_nope = jnp.einsum("btr,rhq->bthq", cq, p["w_uq_nope"])
    q_rope = jnp.einsum("btr,rhq->bthq", cq, p["w_uq_rope"])
    ckv = jnp.einsum("btd,dr->btr", x, p["w_dkv"])
    k_rope = jnp.einsum("btd,dq->btq", x, p["w_k_rope"])
    ckv_n = rms_head_norm(ckv, p["kv_norm_scale"], cfg.norm_eps)
    k_nope = jnp.einsum("btr,rhq->bthq", ckv_n, p["w_uk_nope"])
    v = jnp.einsum("btr,rhv->bthv", ckv_n, p["w_uv"])
    # rope on the decoupled block (shared key, per-head query)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope1 = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    tap(taps, "q", q_nope)
    tap(taps, "k", k_nope)
    k_rope_h = jnp.broadcast_to(k_rope1, (B, T, cfg.n_heads, m.qk_rope_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    q_full, k_full, v = constrain_qkv(q_full, k_full, v)
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    o = flash_ops.attention(q_full, k_full, v, causal=True, scale=scale)
    y = jnp.einsum("bthv,hvd->btd", o, p["wo"])
    cache = None
    if return_cache:
        cache = {"ckv": ckv_n, "k_rope": k_rope1[:, :, 0, :],
                 "pos": jnp.full((B,), T, jnp.int32)}
    return y, cache


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------

def apply_cross_attn(p, x, mem, cfg, *, taps=None):
    """x: (B, T, D) decoder states; mem: (B, S, D) encoder memory."""
    dt = x.dtype
    q = jnp.einsum("btd,dhq->bthq", x, p["wq"])
    k = jnp.einsum("bsd,dhq->bshq", mem, p["wk"])
    v = jnp.einsum("bsd,dhv->bshv", mem, p["wv"])
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    tap(taps, "q", q)
    tap(taps, "k", k)
    scale = 1.0 / np.sqrt(cfg.qk_full)
    o = flash_ops.attention(q, k, v, causal=False, scale=scale)
    return jnp.einsum("bthv,hvd->btd", o, p["wo"])


def precompute_cross_cache(p, mem, cfg):
    k = jnp.einsum("bsd,dhq->bshq", mem, p["wk"])
    v = jnp.einsum("bsd,dhv->bshv", mem, p["wv"])
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return {"k_mem": k, "v_mem": v}


# ---------------------------------------------------------------------------
# decode (one new token against a KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg, kind: str, batch: int, max_len: int):
    """Allocate an empty KV cache for one attention layer."""
    dt = dtype_of(cfg)
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    S = min(max_len, cfg.sliding_window) if kind == "swa" else max_len
    dq, dv = cfg.eff_qk, cfg.d_head
    c = {
        "k": jnp.zeros((batch, S, cfg.n_kv_heads, dq), dt),
        "v": jnp.zeros((batch, S, cfg.n_kv_heads, dv), dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if kind == "swa":
        c["abs_pos"] = jnp.full((batch, S), -1, jnp.int32)
    return c


def decode_attn(p, x, cache, cfg, kind):
    """x: (B, 1, D) one new token. Returns (y, new_cache)."""
    if cfg.mla is not None and "w_dq" in p:
        return _decode_mla(p, x, cache, cfg)
    B = x.shape[0]
    pos = cache["pos"]                          # (B,) current length
    positions = pos[:, None]                    # (B, 1)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, kind, None)
    S = cache["k"].shape[1]
    slot = jnp.mod(pos, S) if kind == "swa" else pos
    k = _scatter_time(cache["k"], k_new[:, 0], slot)
    v = _scatter_time(cache["v"], v_new[:, 0], slot)
    if kind == "swa":
        abs_pos = _scatter_time(cache["abs_pos"][..., None],
                                pos[:, None], slot)[..., 0]
        valid = (abs_pos >= 0) & (abs_pos >= (pos[:, None] - S + 1))
    else:
        key_idx = jnp.arange(S)[None, :]
        valid = key_idx <= pos[:, None]
    scale = 1.0 / np.sqrt(cfg.qk_full)
    y = _decode_sdpa(q, k, v, valid, scale, cfg)
    o = jnp.einsum("bhv,hvd->bd", y, p["wo"])[:, None, :]
    new_cache = dict(cache, k=k, v=v, pos=pos + 1)
    if kind == "swa":
        new_cache["abs_pos"] = abs_pos
    return o, new_cache


def _scatter_time(buf, val, slot):
    """buf: (B, S, ...), val: (B, ...), slot: (B,) — write val at [b, slot[b]].

    Indexed scatter (not a one-hot rewrite): XLA updates in place, so the
    decode step never re-materializes the cache (§Perf iteration G1)."""
    B = buf.shape[0]
    return buf.at[jnp.arange(B), slot].set(val.astype(buf.dtype))


def _decode_sdpa(q, k, v, valid, scale, cfg):
    """q: (B,1,H,dq); k/v: (B,S,Hkv,d); valid: (B,S) -> (B,H,dv).

    Dispatches to the split-KV flash-decoding Pallas kernel on TPU
    (repro.kernels.flash_decode); the jnp path contracts the cache in its
    storage dtype with fp32 accumulation (preferred_element_type) — a
    wholesale .astype(f32) would materialize an fp32 copy of the entire KV
    cache per step (§Perf iteration G1).
    """
    import os
    if jax.default_backend() == "tpu" or os.environ.get("REPRO_DECODE_IMPL"):
        from repro.kernels.flash_decode import ops as fd_ops
        return fd_ops.decode_attention(q[:, 0], k, v, valid, scale=scale)
    B, _, H, dq = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q[:, 0].reshape(B, Hkv, g, dq)
    logits = jnp.einsum("bngq,bsnq->bngs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bngs,bsnv->bngv", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, -1).astype(q.dtype)


def _decode_mla(p, x, cache, cfg):
    """MLA decode with the absorbed-matmul trick (latent-space cache)."""
    m = cfg.mla
    B = x.shape[0]
    pos = cache["pos"]
    positions = pos[:, None]
    cq = jnp.einsum("btd,dr->btr", x, p["w_dq"])
    cq = rms_head_norm(cq, p["q_norm_scale"], cfg.norm_eps)
    q_nope = jnp.einsum("btr,rhq->bthq", cq, p["w_uq_nope"])[:, 0]
    q_rope = jnp.einsum("btr,rhq->bthq", cq, p["w_uq_rope"])
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)[:, 0]
    ckv_new = jnp.einsum("btd,dr->btr", x, p["w_dkv"])
    ckv_new = rms_head_norm(ckv_new, p["kv_norm_scale"], cfg.norm_eps)
    kr_new = jnp.einsum("btd,dq->btq", x, p["w_k_rope"])
    kr_new = apply_rope(kr_new[:, :, None, :], positions,
                        cfg.rope_theta)[:, 0, 0]
    ckv = _scatter_time(cache["ckv"], ckv_new[:, 0], pos)
    krope = _scatter_time(cache["k_rope"], kr_new, pos)
    # absorb W_uk into q: q_eff (B,H,r)
    q_eff = jnp.einsum("bhq,rhq->bhr", q_nope, p["w_uk_nope"])
    S = ckv.shape[1]
    lo_n = jnp.einsum("bhr,bsr->bhs", q_eff.astype(jnp.float32),
                      ckv.astype(jnp.float32))
    lo_r = jnp.einsum("bhq,bsq->bhs", q_rope.astype(jnp.float32),
                      krope.astype(jnp.float32))
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    logits = (lo_n + lo_r) * scale
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, ckv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", o_lat.astype(x.dtype), p["w_uv"])
    y = jnp.einsum("bhv,hvd->bd", o, p["wo"])[:, None, :]
    return y, dict(cache, ckv=ckv, k_rope=krope, pos=pos + 1)


def decode_cross_attn(p, x, cross_cache, cfg):
    """Decoder cross-attention during decode: memory K/V precomputed."""
    q = jnp.einsum("btd,dhq->bthq", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    k, v = cross_cache["k_mem"], cross_cache["v_mem"]
    S = k.shape[1]
    valid = jnp.ones((x.shape[0], S), bool)
    y = _decode_sdpa(q, k, v, valid, 1.0 / np.sqrt(cfg.qk_full), cfg)
    return jnp.einsum("bhv,hvd->bd", y, p["wo"])[:, None, :]
