"""MLP mixers: dense (plain / gated) and Mixture-of-Experts.

CORP integration: the tap ``h`` is the activation entering the *second*
linear map (Eq. 1 of the paper: ``y = W x + b`` with ``x`` the hidden
activation). For gated (GLU) MLPs the hidden activation is
``act(x W_g) * (x W_u)`` — pruning a hidden channel removes a column of both
W_g and W_u plus a row of W_d, exactly one structured unit. For MoE the tap
additionally carries the dispatch mask so statistics are expert-conditional.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import activation, dense_init, dtype_of, tap


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff=None, bias=None):
    dt = dtype_of(cfg)
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.eff_d_ff
    bias = cfg.mlp_kind == "plain" if bias is None else bias
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "glu":
        p = {
            "wg": dense_init(ks[0], (D, F), dt),
            "wu": dense_init(ks[1], (D, F), dt),
            "wd": dense_init(ks[2], (F, D), dt),
        }
    else:
        p = {
            "wu": dense_init(ks[0], (D, F), dt),
            "wd": dense_init(ks[1], (F, D), dt),
        }
    if bias:
        p["bu"] = jnp.zeros((F,), jnp.float32)
        p["bd"] = jnp.zeros((D,), jnp.float32)
        if cfg.mlp_kind == "glu":
            p["bg"] = jnp.zeros((F,), jnp.float32)
    return p


def apply_mlp(p, x, cfg, taps=None):
    """x: (..., D) -> (..., D)."""
    act = activation(cfg.act)
    dt = x.dtype
    u = x @ p["wu"]
    if "bu" in p:
        u = u + p["bu"].astype(dt)
    if "wg" in p:
        gpre = x @ p["wg"]
        if "bg" in p:
            gpre = gpre + p["bg"].astype(dt)
        h = act(gpre) * u
    else:
        h = act(u)
    tap(taps, "h", h)
    y = h @ p["wd"]
    if "bd" in p:
        y = y + p["bd"].astype(dt)
    return y


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style grouped one-hot dispatch with capacity)
# ---------------------------------------------------------------------------

def init_moe(key, cfg):
    dt = dtype_of(cfg)
    m = cfg.moe
    D, E = cfg.d_model, cfg.eff_num_experts
    F = cfg.eff_d_ff if cfg.d_ff_kept is not None else m.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32, scale=0.02),
        "wg": dense_init(ks[1], (E, D, F), dt),
        "wu": dense_init(ks[2], (E, D, F), dt),
        "wd": dense_init(ks[3], (E, F, D), dt),
    }
    if m.num_shared > 0:
        # shared experts = one dense MLP of num_shared * d_expert hidden
        shared_cfg = cfg.replace(d_ff=m.num_shared * m.d_expert,
                                 d_ff_kept=(None if cfg.d_ff_kept is None
                                            else m.num_shared * cfg.d_ff_kept))
        p["shared"] = init_mlp(ks[4], shared_cfg)
    return p


def _group_tokens(x, target=2048):
    """(B, T, D) -> (G, tg, D) with tg <= target dividing B*T."""
    B, T, D = x.shape
    n = B * T
    tg = min(target, n)
    while n % tg:
        tg -= 1
    return x.reshape(n // tg, tg, D), n


def apply_moe(p, x, cfg, taps=None, train=False):
    """Top-k routed experts with capacity; returns (y, aux_loss)."""
    m = cfg.moe
    E, K = cfg.eff_num_experts, m.top_k
    B, T, D = x.shape
    xg, n = _group_tokens(x)
    G, tg, _ = xg.shape
    C = max(K, int(np.ceil(tg * K * m.capacity_factor / E)))
    C = min(C, tg)

    logits = (xg.astype(jnp.float32) @ p["router"])        # (G, tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)           # (G, tg, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G, tg, K, E)
    flat = onehot.reshape(G, tg * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # (G, tg*K, E)
    pos = pos.reshape(G, tg, K, E)
    within_cap = pos < C
    keep = onehot * within_cap                               # (G, tg, K, E)
    # position of each (token, k) inside its *chosen* expert queue: (G, tg, K)
    pos_k = jnp.sum(pos * onehot, axis=-1)
    slot_k = jax.nn.one_hot(pos_k.astype(jnp.int32), C, dtype=jnp.float32)
    # dispatch: (G, tg, E, C) — contraction over K avoids a (K,E,C) blowup
    dispatch = jnp.einsum("gtke,gtkc->gtec", keep, slot_k)
    combine = jnp.einsum("gtke,gtk,gtkc->gtec", keep, gate_vals, slot_k)

    dt = x.dtype
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dt), xg)  # (G,E,C,D)
    act = activation(cfg.act)
    u = jnp.einsum("gecd,edf->gecf", xe, p["wu"])
    h = act(jnp.einsum("gecd,edf->gecf", xe, p["wg"])) * u
    tap(taps, "moe_h", h)
    if taps is not None:
        taps["moe_mask"] = jnp.einsum("gtec->gec", dispatch).astype(jnp.float32)
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    if "bd_moe" in p:   # CORP hidden-channel compensation bias (per expert)
        # inside the expert output, before combine: dispatched tokens get
        # it gate-weighted, empty capacity slots are zeroed by combine
        ye = ye + p["bd_moe"].astype(ye.dtype)[None, :, None, :]
    if taps is not None:
        # expert-removal compensation statistics (repro.core.stats._p1_moe):
        # block input x_t plus per-token per-expert *contributions*
        # (gate-weighted expert outputs) — removed experts' contributions
        # are regressed onto x, whose distribution is routing-invariant
        tap(taps, "moe_x", xg)
        tap(taps, "moe_yc",
            jnp.einsum("gtec,gecd->gted", combine.astype(dt), ye))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(dt), ye)
    if "moe_resid" in p:   # CORP expert-removal compensation (input map)
        y = y + jnp.einsum("gtd,dc->gtc", xg.astype(jnp.float32),
                           p["moe_resid"]).astype(dt)
    if "moe_out_b" in p:   # CORP expert-removal compensation bias
        y = y + p["moe_out_b"].astype(dt)
    y = y.reshape(B, T, D)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg, taps=taps)

    # load-balancing aux loss (Switch-style)
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))       # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
