"""ViT / DeiT: the paper's own architecture family.

Plain pre-norm ViT: patch embedding (conv-as-linear on flattened patches, or
a stub taking precomputed patch embeddings), cls token, learned positional
embeddings, bidirectional attention blocks, classification head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain
from repro.models import blocks as blk
from repro.models.common import (apply_norm, dense_init, dtype_of,
                                 embed_init, init_norm)


def num_patches(cfg) -> int:
    return (cfg.img_size // cfg.patch) ** 2


def init_vit(key, cfg):
    ks = jax.random.split(key, 6 + cfg.n_layers)
    dt = dtype_of(cfg)
    N = num_patches(cfg)
    params = {
        "cls": jnp.zeros((1, 1, cfg.d_model), dt),
        "pos": embed_init(ks[0], (1, N + 1, cfg.d_model), dt),
        "final_norm": init_norm(ks[1], cfg),
        "class_head": dense_init(ks[2], (cfg.d_model, cfg.n_classes), dt,
                                 scale=0.02),
        "head_bias": jnp.zeros((cfg.n_classes,), jnp.float32),
    }
    if cfg.frontend == "patch_conv":
        pdim = cfg.patch * cfg.patch * 3
        params["patch_w"] = dense_init(ks[3], (pdim, cfg.d_model), dt)
        params["patch_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    # scan-over-layers: homogeneous stack
    def init_pos(k):
        return blk.init_block(k, cfg, "attn", False)
    pos_keys = jax.random.split(ks[4], cfg.n_layers)
    params["seg0"] = {"p0": jax.vmap(init_pos)(pos_keys)}
    return params


def patchify(images, cfg):
    """images: (B, H, W, 3) -> (B, N, p*p*3)."""
    B, H, W, C = images.shape
    p = cfg.patch
    x = images.reshape(B, H // p, p, W // p, p, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, (H // p) * (W // p),
                                              p * p * C)
    return x


def apply_vit(params, inputs, cfg, *, taps=None, train=False, remat=None):
    """inputs: images (B,H,W,3) if frontend='patch_conv', else patch
    embeddings (B, N, D). Returns logits (B, n_classes)."""
    dt = dtype_of(cfg)
    if cfg.frontend == "patch_conv":
        x = patchify(inputs.astype(dt), cfg) @ params["patch_w"] \
            + params["patch_b"].astype(dt)
    else:
        x = inputs.astype(dt)
    B, N, D = x.shape
    cls = jnp.broadcast_to(params["cls"], (B, 1, D))
    x = jnp.concatenate([cls, x], axis=1)
    x = x + params["pos"][:, :N + 1].astype(dt)
    x = constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(N + 1, dtype=jnp.int32)[None],
                                 (B, N + 1))

    def body(carry, pslice):
        x = carry
        t = {} if taps is not None else None
        x, _ = blk.apply_block(pslice["p0"], x, cfg, "attn", False,
                               positions=positions, taps=t,
                               mask_kind="full", train=train)
        x = constrain(x, "residual")
        return x, (t or {})

    remat = train if remat is None else remat
    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, ys = jax.lax.scan(body, x, params["seg0"])
    if taps is not None:
        for k, v in ys.items():
            taps[f"seg0/p0/{k}"] = v
    x = apply_norm(params["final_norm"], x, cfg)
    pooled = x[:, 0] if cfg.pool == "cls" else x.mean(axis=1)
    logits = pooled @ params["class_head"] + params["head_bias"].astype(dt)
    return logits.astype(jnp.float32)


def vit_loss(params, batch, cfg, *, train=True):
    """batch: {'images' | 'embeds', 'labels' (B,)} -> CE loss."""
    inputs = batch.get("images", batch.get("embeds"))
    logits = apply_vit(params, inputs, cfg, train=train)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def vit_accuracy(params, inputs, labels, cfg):
    logits = apply_vit(params, inputs, cfg)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
