"""Shared model components: norms, rope, activations, initializers, taps."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------

def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def param_dtype(cfg) -> jnp.dtype:
    # params stored in the compute dtype (bf16 at scale); norm scales fp32
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# initializers (all pure; usable under jax.eval_shape)
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    if scale is None:
        scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(key, cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm_kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(p, x, cfg):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps):
    """QK-norm over the last (head) dim."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> np.ndarray:
    """Per-pair inverse frequencies; dim must be even."""
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float64) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, D) with D even, positions: (..., T) int32."""
    d = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., T, D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# tap (activation tape) utilities
# ---------------------------------------------------------------------------

_TAP_DTYPE = jnp.float32


class tap_dtype:
    """Trace-time context setting the dtype activation taps are emitted in.

    fp32 (default) maximises statistics fidelity; bf16 halves the
    calibration pass's activation HBM traffic end-to-end — the gram kernel
    streams bf16 tiles and still accumulates fp32 in VMEM (the
    ``stats_dtype`` knob of ``repro.core.calibrate.CalibrationEngine``
    wraps the model forward in this context). A Python-level knob: it must
    be active while the forward is *traced*, which the engine guarantees by
    entering it inside its jitted reduce function.
    """

    def __init__(self, dtype):
        self.dtype = jnp.dtype(dtype)

    def __enter__(self):
        global _TAP_DTYPE
        self._prev, _TAP_DTYPE = _TAP_DTYPE, self.dtype
        return self

    def __exit__(self, *exc):
        global _TAP_DTYPE
        _TAP_DTYPE = self._prev
        return False


def tap(taps: dict | None, name: str, value):
    """Record an intermediate activation for CORP calibration.

    ``taps`` is None when not taping (no memory cost). Values are stored in
    the active ``tap_dtype`` — fp32 by default; every statistics reduction
    downstream accumulates in fp32 regardless of the tape dtype.
    """
    if taps is not None:
        taps[name] = value.astype(_TAP_DTYPE)


def merge_taps(dst: dict | None, src: dict, prefix: str):
    if dst is not None:
        for k, v in src.items():
            dst[f"{prefix}/{k}" if prefix else k] = v
