"""Encoder-decoder model (seamless-m4t family).

Encoder consumes precomputed frame embeddings (audio frontend is a stub per
the assignment); decoder is a causal LM with cross-attention to the encoder
memory. Sinusoidal absolute positions (no rope) — which makes the paper's
full-matrix QK compensation exactly applicable (DESIGN.md class-1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distrib.sharding import constrain
from repro.models import blocks as blk
from repro.models.common import apply_norm, dtype_of, embed_init, init_norm


def _sinusoid(T: int, D: int):
    pos = np.arange(T)[:, None]
    i = np.arange(D // 2)[None, :]
    ang = pos / (10000 ** (2 * i / D))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


def init_encdec(key, cfg):
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    params = {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dt),
        "enc_final_norm": init_norm(ks[1], cfg),
        "final_norm": init_norm(ks[2], cfg),
        "head": embed_init(ks[3], (cfg.d_model, cfg.padded_vocab), dt),
    }

    def init_enc(k):
        return blk.init_block(k, cfg, "attn", False)

    def init_dec(k):
        return blk.init_block(k, cfg, "attn", False, cross=True)

    params["enc"] = {"p0": jax.vmap(init_enc)(
        jax.random.split(ks[4], cfg.n_enc_layers))}
    params["dec"] = {"p0": jax.vmap(init_dec)(
        jax.random.split(ks[5], cfg.n_layers))}
    return params


def _run_stack(stack, x, cfg, *, positions, taps, mask_kind, mem, prefix,
               train, remat=False):
    specs = [("attn", False)]

    def body(carry, pslice):
        x = carry
        t = {} if taps is not None else None
        x, _ = blk.apply_block(pslice["p0"], x, cfg, "attn", False,
                               positions=positions, taps=t,
                               mask_kind=mask_kind, mem=mem, train=train)
        x = constrain(x, "residual")
        return x, (t or {})

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, ys = jax.lax.scan(body, x, stack)
    if taps is not None:
        for k, v in ys.items():
            taps[f"{prefix}/p0/{k}"] = v
    return x


def encode(params, frames, cfg, *, taps=None, train=False, remat=False):
    """frames: (B, S, D) stub frontend embeddings -> encoder memory."""
    B, S, D = frames.shape
    x = frames.astype(dtype_of(cfg)) + _sinusoid(S, D).astype(dtype_of(cfg))
    x = constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _run_stack(params["enc"], x, cfg, positions=positions, taps=taps,
                   mask_kind="full", mem=None, prefix="enc", train=train,
                   remat=remat)
    return apply_norm(params["enc_final_norm"], x, cfg)


def apply_encdec(params, frames, tokens, cfg, *, taps=None, train=False,
                 remat=None):
    """Returns (logits (B, T, padded_vocab), aux)."""
    remat = train if remat is None else remat
    mem = encode(params, frames, cfg, taps=taps, train=train, remat=remat)
    B, T = tokens.shape
    x = params["embed"][tokens]
    x = x + _sinusoid(T, cfg.d_model).astype(x.dtype)
    x = constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = _run_stack(params["dec"], x, cfg, positions=positions, taps=taps,
                   mask_kind="causal", mem=mem, prefix="dec", train=train,
                   remat=remat)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = constrain(x @ params["head"], "logits")
    return logits, jnp.zeros((), jnp.float32)


def encdec_loss(params, batch, cfg, *, train=True):
    """batch: {'frames': (B,S,D), 'tokens': (B,T), 'labels': (B,T)}."""
    logits, _ = apply_encdec(params, batch["frames"], batch["tokens"], cfg,
                             train=train)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def encdec_prefill(params, frames, tokens, cfg, max_len: int, lengths=None):
    """Encode + teacher-forced decoder prefill. Returns (logits, cache).

    ``lengths`` (B,) enables ragged decoder prompts (right-padded tokens):
    causal self-attention keeps decoder cache rows < lengths exact, and
    cross-attention/MLP are per-position, so only the logits gather and the
    cache ``pos`` need the true length. ``frames`` must be unpadded — the
    encoder memory is attended in full.
    """
    from repro.models import attention as attn_mod
    from repro.models import lm as lm_mod
    mem = encode(params, frames, cfg)
    B, T = tokens.shape
    x = params["embed"][tokens]
    x = x + _sinusoid(T, cfg.d_model).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    caches = []
    p_stack = params["dec"]["p0"]
    n = cfg.n_layers

    def one_layer(p, x):
        h = apply_norm(p["ln1"], x, cfg)
        y, c = attn_mod.apply_attn(p["mixer"], h, cfg, "attn",
                                   positions=positions, return_cache=True)
        c = lm_mod._pad_cache(c, max_len)
        x = x + y
        h = apply_norm(p["ln_cross"], x, cfg)
        x = x + attn_mod.apply_cross_attn(p["cross"], h, mem, cfg)
        h = apply_norm(p["ln2"], x, cfg)
        from repro.models import mlp as mlp_mod
        x = x + mlp_mod.apply_mlp(p["mlp"], h, cfg)
        cc = attn_mod.precompute_cross_cache(p["cross"], mem, cfg)
        return x, {"self": c, "cross": cc}

    def body(carry, pslice):
        x = carry
        x, c = one_layer(pslice, x)
        return x, c

    x, cache_stack = jax.lax.scan(body, x, p_stack)
    if lengths is None:
        x_last = x[:, -1:]
        pos = jnp.full((B,), T, jnp.int32)
    else:
        idx = (lengths.astype(jnp.int32) - 1)[:, None, None]
        x_last = jnp.take_along_axis(x, idx, axis=1)
        cache_stack = lm_mod.override_cache_pos(cache_stack, lengths)
        pos = lengths.astype(jnp.int32)
    x = apply_norm(params["final_norm"], x_last, cfg)
    return x @ params["head"], {"dec": cache_stack, "pos": pos}


def encdec_decode_step(params, token, cache, cfg):
    from repro.models import attention as attn_mod
    x = params["embed"][token]
    pos = cache["pos"]
    # absolute sinusoidal position of the new token
    D = cfg.d_model
    i = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos.astype(jnp.float32)[:, None] / (10000.0 ** (2 * i / D))
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (B, D)
    x = x + pe[:, None, :].astype(x.dtype)

    def body(carry, slices):
        x = carry
        pslice, cslice = slices
        h = apply_norm(pslice["ln1"], x, cfg)
        y, c_new = attn_mod.decode_attn(pslice["mixer"], h, cslice["self"],
                                        cfg, "attn")
        x = x + y
        h = apply_norm(pslice["ln_cross"], x, cfg)
        x = x + attn_mod.decode_cross_attn(pslice["cross"], h,
                                           cslice["cross"], cfg)
        h = apply_norm(pslice["ln2"], x, cfg)
        from repro.models import mlp as mlp_mod
        x = x + mlp_mod.apply_mlp(pslice["mlp"], h, cfg)
        return x, {"self": c_new, "cross": cslice["cross"]}

    x, new_stack = jax.lax.scan(body, x, (params["dec"]["p0"], cache["dec"]))
    x = apply_norm(params["final_norm"], x, cfg)
    return x @ params["head"], {"dec": new_stack, "pos": pos + 1}
