"""Transformer block assembly: (norm -> mixer -> norm -> mlp) per layer spec.

A block's mixer is one of 'attn' | 'swa' | 'mamba' | 'rwkv'; its MLP is dense
or MoE (per ``cfg.layer_is_moe``). Decoder blocks in enc-dec models carry an
extra cross-attention sub-layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models.common import apply_norm, init_norm, merge_taps


def init_block(key, cfg, kind: str, is_moe: bool, *, cross: bool = False,
               dense_ff: int | None = None):
    ks = jax.random.split(key, 5)
    p = {"ln1": init_norm(ks[0], cfg)}
    if kind in ("attn", "swa"):
        p["mixer"] = attn_mod.init_attn(ks[1], cfg, kind)
    elif kind == "mamba":
        p["mixer"] = ssm_mod.init_mamba(ks[1], cfg)
    elif kind == "rwkv":
        p["mixer"] = ssm_mod.init_rwkv_time(ks[1], cfg)
    else:
        raise ValueError(kind)
    p["ln2"] = init_norm(ks[2], cfg)
    if kind == "rwkv":
        p["mlp"] = ssm_mod.init_rwkv_channel(ks[3], cfg)
    elif is_moe:
        p["mlp"] = mlp_mod.init_moe(ks[3], cfg)
    else:
        if dense_ff is not None:
            p["mlp"] = mlp_mod.init_mlp(ks[3], cfg, d_ff=dense_ff)
        else:
            p["mlp"] = mlp_mod.init_mlp(ks[3], cfg)
    if cross:
        kc = jax.random.split(ks[4], 2)
        p["ln_cross"] = init_norm(kc[0], cfg)
        p["cross"] = attn_mod.init_attn(kc[1], cfg, "attn", cross=True)
    return p


def apply_block(p, x, cfg, kind: str, is_moe: bool, *, positions,
                taps=None, mem=None, mask_kind="causal", train=False):
    """Full-sequence block. Returns (x, aux_loss)."""
    t = {} if taps is not None else None
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["ln1"], x, cfg)
    if kind in ("attn", "swa"):
        y, _ = attn_mod.apply_attn(p["mixer"], h, cfg, kind,
                                   positions=positions, taps=t,
                                   mask_kind=mask_kind)
    elif kind == "mamba":
        y, _ = ssm_mod.apply_mamba(p["mixer"], h, cfg, taps=t)
    else:  # rwkv
        y, _ = ssm_mod.apply_rwkv_time(p["mixer"], h, cfg, taps=t)
    x = x + y
    if "cross" in p and mem is not None:
        tc = {} if taps is not None else None
        h = apply_norm(p["ln_cross"], x, cfg)
        yc = attn_mod.apply_cross_attn(p["cross"], h, mem, cfg, taps=tc)
        if t is not None:
            for kname, vv in tc.items():
                t["cross_" + kname] = vv
        x = x + yc
    h = apply_norm(p["ln2"], x, cfg)
    if kind == "rwkv":
        y, _ = ssm_mod.apply_rwkv_channel(p["mlp"], h, cfg, taps=t)
    elif is_moe:
        y, aux = mlp_mod.apply_moe(p["mlp"], h, cfg, taps=t, train=train)
    else:
        y = mlp_mod.apply_mlp(p["mlp"], h, cfg, taps=t)
    x = x + y
    if taps is not None:
        merge_taps(taps, t, "")
    return x, aux


def init_block_cache(cfg, kind: str, batch: int, max_len: int):
    if kind in ("attn", "swa"):
        return attn_mod.init_cache(cfg, kind, batch, max_len)
    if kind == "mamba":
        return ssm_mod.init_mamba_state(cfg, batch)
    if kind == "rwkv":
        return ssm_mod.init_rwkv_state(cfg, batch)
    raise ValueError(kind)


def decode_block(p, x, cache, cfg, kind: str, is_moe: bool, *,
                 cross_cache=None):
    """One-token decode. x: (B,1,D). Returns (x, new_cache)."""
    h = apply_norm(p["ln1"], x, cfg)
    if kind in ("attn", "swa"):
        y, new_cache = attn_mod.decode_attn(p["mixer"], h, cache, cfg, kind)
    elif kind == "mamba":
        y, ms = ssm_mod.apply_mamba(p["mixer"], h, cfg, state=cache)
        new_cache = ms
    else:  # rwkv
        y, ts = ssm_mod.apply_rwkv_time(p["mixer"], h, cfg,
                                        state=cache["time"])
        new_cache = dict(cache, time=ts)
    x = x + y
    if "cross" in p and cross_cache is not None:
        h = apply_norm(p["ln_cross"], x, cfg)
        x = x + attn_mod.decode_cross_attn(p["cross"], h, cross_cache, cfg)
    h = apply_norm(p["ln2"], x, cfg)
    if kind == "rwkv":
        y, cs = ssm_mod.apply_rwkv_channel(p["mlp"], h, cfg,
                                           state=cache["channel"])
        new_cache = dict(new_cache, channel=cs)
    elif is_moe:
        y, _ = mlp_mod.apply_moe(p["mlp"], h, cfg)
    else:
        y = mlp_mod.apply_mlp(p["mlp"], h, cfg)
    return x + y, new_cache
