"""Attention-free mixers: RWKV-6 (Finch) time/channel mix and Mamba.

CORP applicability (DESIGN.md §Arch-applicability):
  * RWKV-6 time-mix has no QK bilinear logits -> QK pruning inapplicable.
  * RWKV channel-mix is a two-matrix MLP -> hidden channels prunable with
    the paper's affine compensation (tap 'h').
  * Mamba inner channels pass only through channel-wise ops (depthwise conv,
    per-channel SSM, gate) between in_proj and out_proj -> prunable as
    MLP-like hidden dims (beyond-paper extension; tap 'mamba_y' feeds the
    same closed-form machinery against out_proj).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distrib.sharding import constrain
from repro.kernels.wkv6 import ops as wkv_ops
from repro.models.common import dense_init, dtype_of, tap


# ---------------------------------------------------------------------------
# RWKV-6
# ---------------------------------------------------------------------------

def init_rwkv_time(key, cfg):
    dt = dtype_of(cfg)
    D = cfg.d_model
    N = cfg.rwkv.head_dim
    H = D // N
    r = cfg.rwkv.decay_lora
    ks = jax.random.split(key, 10)
    return {
        "mu_r": jnp.full((D,), 0.5, jnp.float32),
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "mu_v": jnp.full((D,), 0.5, jnp.float32),
        "mu_w": jnp.full((D,), 0.5, jnp.float32),
        "mu_g": jnp.full((D,), 0.5, jnp.float32),
        "w0": jnp.full((D,), -2.0, jnp.float32),
        "w_lora_a": dense_init(ks[0], (D, r), jnp.float32),
        "w_lora_b": (jax.random.normal(ks[1], (r, D), jnp.float32) * 1e-2),
        "u": (jax.random.normal(ks[2], (H, N), jnp.float32) * 0.1),
        "wr": dense_init(ks[3], (D, D), dt),
        "wk": dense_init(ks[4], (D, D), dt),
        "wv": dense_init(ks[5], (D, D), dt),
        "wg": dense_init(ks[6], (D, D), dt),
        "wo": dense_init(ks[7], (D, D), dt, scale=1.0 / np.sqrt(D)),
        "ln_scale": jnp.ones((D,), jnp.float32),
        "ln_bias": jnp.zeros((D,), jnp.float32),
    }


def _shift(x, prev=None):
    """Token shift: x_{t-1} with x_{-1} = prev (or zeros)."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def apply_rwkv_time(p, x, cfg, taps=None, state=None):
    """x: (B, T, D). state: {'shift': (B,D), 'wkv': (B,H,N,N)} or None.

    Returns (y, new_state).
    """
    B, T, D = x.shape
    N = cfg.rwkv.head_dim
    H = D // N
    prev = state["shift"] if state is not None else None
    xs = _shift(x, prev)
    r = _mix(x, xs, p["mu_r"]) @ p["wr"]
    k = _mix(x, xs, p["mu_k"]) @ p["wk"]
    v = _mix(x, xs, p["mu_v"]) @ p["wv"]
    g = _mix(x, xs, p["mu_g"]) @ p["wg"]
    xw = _mix(x, xs, p["mu_w"]).astype(jnp.float32)
    # data-dependent decay (the v6 feature)
    dd = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(p["w0"] + dd))                  # (B,T,D) in (0,1)

    hd = lambda z: z.reshape(B, T, H, N)
    s0 = state["wkv"] if state is not None else None
    y, s_new = wkv_ops.wkv6(hd(r), hd(k), hd(v),
                            hd(w.astype(x.dtype)), p["u"], s0)
    y = y.reshape(B, T, D).astype(jnp.float32)
    # per-head group norm
    yh = y.reshape(B, T, H, N)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, T, D) * p["ln_scale"] + p["ln_bias"]
    y = (y.astype(x.dtype) * jax.nn.silu(g)) @ p["wo"]
    new_state = {"shift": x[:, -1], "wkv": s_new}
    return y, new_state


def init_rwkv_channel(key, cfg):
    dt = dtype_of(cfg)
    D, F = cfg.d_model, cfg.eff_d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "mu_r": jnp.full((D,), 0.5, jnp.float32),
        "wk": dense_init(ks[0], (D, F), dt),
        "wv": dense_init(ks[1], (F, D), dt),
        "wr": dense_init(ks[2], (D, D), dt),
    }


def apply_rwkv_channel(p, x, cfg, taps=None, state=None):
    """RWKV channel-mix (the 'MLP'): squared-relu, receptance gate."""
    prev = state["shift"] if state is not None else None
    xs = _shift(x, prev)
    h = jnp.square(jax.nn.relu(_mix(x, xs, p["mu_k"]) @ p["wk"]))
    tap(taps, "h", h)
    kv = h @ p["wv"]
    if "bv_comp" in p:   # CORP compensation bias (added by pruning)
        kv = kv + p["bv_comp"].astype(kv.dtype)
    y = jax.nn.sigmoid(_mix(x, xs, p["mu_r"]) @ p["wr"]) * kv
    return y, {"shift": x[:, -1]}


def init_rwkv_state(cfg, batch):
    D = cfg.d_model
    N = cfg.rwkv.head_dim
    H = D // N
    return {
        "time": {"shift": jnp.zeros((batch, D), dtype_of(cfg)),
                 "wkv": jnp.zeros((batch, H, N, N), jnp.float32)},
        "channel": {"shift": jnp.zeros((batch, D), dtype_of(cfg))},
    }


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------

def _dt_rank(cfg) -> int:
    return max(1, int(np.ceil(cfg.d_model / 16)))


def init_mamba(key, cfg):
    dt = dtype_of(cfg)
    D = cfg.d_model
    di = cfg.eff_d_inner
    ns = cfg.mamba.d_state
    dc = cfg.mamba.d_conv
    dr = _dt_rank(cfg)
    ks = jax.random.split(key, 7)
    a = jnp.tile(jnp.arange(1, ns + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di), dt),
        "conv_w": dense_init(ks[1], (dc, di), jnp.float32, scale=0.2),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], (di, dr + 2 * ns), dt),
        "dt_proj": dense_init(ks[3], (dr, di), jnp.float32),
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),  # softplus ~ small dt
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, D), dt),
    }


def _causal_conv(x, w, b, prev=None):
    """Depthwise causal conv. x: (B,T,di), w: (dc,di), prev: (B,dc-1,di)."""
    dc = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(dc))
    return y + b.astype(x.dtype), xp[:, -(dc - 1):]


def apply_mamba(p, x, cfg, taps=None, state=None, scan_chunk=256):
    """x: (B,T,D). state: {'conv': (B,dc-1,di), 'ssm': (B,di,ns)} or None.

    §Perf iteration J1: the inner dim is sequence-unsharded but
    *channel-sharded* over 'model' (selective-scan state is per-channel, so
    channel sharding needs zero cross-chip traffic in the recurrence), and
    ALL discretization tensors (dt, dA, dBx — the (B,T,di,ns) blow-ups) are
    computed per chunk inside the sequential scan instead of materializing
    for the full sequence.
    """
    B, T, D = x.shape
    di = cfg.eff_d_inner
    ns = cfg.mamba.d_state
    dr = _dt_rank(cfg)
    xz = x @ p["in_proj"]
    xi, z = xz[..., :di], xz[..., di:]
    xi = constrain(xi, "mamba_inner")
    conv_prev = state["conv"] if state is not None else None
    xc, conv_new = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_prev)
    xc = constrain(jax.nn.silu(xc), "mamba_inner")
    A = -jnp.exp(p["a_log"])                                  # (di,ns)

    def discretize(xc_blk):
        """(B,L,di) -> per-chunk dt/dA/dBx/C — nothing persists beyond it."""
        xdb = xc_blk @ p["x_proj"]
        dt_in, Bs, Cs = (xdb[..., :dr], xdb[..., dr:dr + ns],
                         xdb[..., dr + ns:])
        dts = jax.nn.softplus(dt_in.astype(jnp.float32) @ p["dt_proj"]
                              + p["dt_bias"])                 # (B,L,di)
        dA = jnp.exp(dts[..., None] * A[None, None])          # (B,L,di,ns)
        dBx = (dts * xc_blk.astype(jnp.float32))[..., None] \
            * Bs.astype(jnp.float32)[..., None, :]
        return dA, dBx, Cs

    h0 = state["ssm"] if state is not None else jnp.zeros((B, di, ns),
                                                          jnp.float32)
    if T == 1:
        dA, dBx, Cs = discretize(xc)
        h = dA[:, 0] * h0 + dBx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, Cs[:, 0].astype(jnp.float32))[:, None]
        h_last = h
    else:
        # chunked scan: sequential over chunks, associative within
        L = min(scan_chunk, T)
        while T % L:
            L -= 1
        nc = T // L
        xcc = xc.reshape(B, nc, L, di).transpose(1, 0, 2, 3)

        def chunk_step(h, xc_blk):
            a, b, Cs = discretize(xc_blk)                    # (B,L,di,ns)

            def comb(e1, e2):
                a1, b1 = e1
                a2, b2 = e2
                return a1 * a2, b1 * a2 + b2
            ac, bc = jax.lax.associative_scan(comb, (a, b), axis=1)
            hs = ac * h[:, None] + bc                        # (B,L,di,ns)
            y_blk = jnp.einsum("bldn,bln->bld", hs,
                               Cs.astype(jnp.float32))
            return hs[:, -1], y_blk

        h_last, yc = jax.lax.scan(chunk_step, h0, xcc)
        y = yc.transpose(1, 0, 2, 3).reshape(B, T, di)
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    tap(taps, "mamba_y", y)
    out = y @ p["out_proj"]
    if "out_b" in p:   # CORP compensation bias (added by pruning)
        out = out + p["out_b"].astype(out.dtype)
    new_state = {"conv": conv_new, "ssm": h_last}
    return out, new_state


def init_mamba_state(cfg, batch):
    di = cfg.eff_d_inner
    return {
        "conv": jnp.zeros((batch, cfg.mamba.d_conv - 1, di), dtype_of(cfg)),
        "ssm": jnp.zeros((batch, di, cfg.mamba.d_state), jnp.float32),
    }
