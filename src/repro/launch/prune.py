"""Distributed CORP pruning driver.

    PYTHONPATH=src python -m repro.launch.prune --arch deit-tiny-reduced \
        --sparsity 0.5 --calib 256 --ckpt-in /tmp/ckpt --out /tmp/pruned

Loads (or initializes) dense params, runs the one-shot CORP pipeline over a
calibration stream, saves the pruned checkpoint + report. With --mesh the
statistics passes run under a device mesh; adding --calib-sharded threads
the mesh into the CalibrationEngine as an explicit sharding contract:
per-unit covariance/Gram blocks column-sharded over the model axis, batch
contributions psum-reduced, no replicated full Sigma on any device. With
--one-traversal the two calibration passes fuse into a single traversal of
the calibration set (speculative pass-2 statistics, docs/pipeline.md).

Every flag is documented in docs/cli.md with a worked end-to-end example.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core import PruneConfig, corp_prune
from repro.data import calib_stream
from repro.launch.mesh import make_mesh, parse_shape
from repro.launch.train import resolve_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser(
        description="One-shot CORP pruning over a calibration stream "
                    "(see docs/cli.md for a worked example)")
    ap.add_argument("--arch", required=True,
                    help="model config name (repro.configs registry), e.g. "
                         "deit-base, granite-8b; '-reduced' suffix shrinks "
                         "it for smoke runs")
    ap.add_argument("--sparsity", type=float, default=0.5,
                    help="fraction of structures to REMOVE from both MLP "
                         "hidden dims and attention qk dims (per-kind "
                         "overrides below win)")
    ap.add_argument("--mlp-sparsity", type=float, default=None,
                    help="override --sparsity for MLP/MoE/mamba hidden "
                         "channels (0 disables MLP pruning)")
    ap.add_argument("--attn-sparsity", type=float, default=None,
                    help="override --sparsity for attention qk dims/rotary "
                         "pairs (0 disables attention pruning)")
    ap.add_argument("--expert-sparsity", type=float, default=0.0,
                    help="fraction of WHOLE routed experts to remove (MoE "
                         "archs only; kept count never drops below top_k; "
                         "removed experts' contributions are ridge-folded "
                         "onto the retained set)")
    ap.add_argument("--calib", type=int, default=128,
                    help="number of calibration samples (unlabeled)")
    ap.add_argument("--calib-batch", type=int, default=8,
                    help="calibration batch size")
    ap.add_argument("--calib-seq", type=int, default=64,
                    help="calibration sequence length (LM archs only)")
    ap.add_argument("--rank-policy", default="combined",
                    help="MLP ranking statistic: act | mag | combined | "
                         "active (repro.core.ranking.mlp_scores)")
    ap.add_argument("--no-compensate", action="store_true",
                    help="rank-only baseline: prune without the closed-form "
                         "ridge compensation (paper ablation)")
    ap.add_argument("--round-to", type=int, default=1,
                    help="round kept counts down to a multiple (TPU lane "
                         "alignment, e.g. 128)")
    ap.add_argument("--lam", type=float, default=1e-4,
                    help="ridge strength, relative to mean(diag(Sigma))")
    ap.add_argument("--ckpt-in", default=None,
                    help="train checkpoint dir to load dense params from "
                         "(newest valid step; fresh init when omitted)")
    ap.add_argument("--out", default=None,
                    help="output dir for the pruned checkpoint + "
                         "report.json (print-only when omitted)")
    ap.add_argument("--mesh", default=None,
                    help="device mesh shape, 'DxM' (data x model) or "
                         "'PxDxM' with a pod axis, e.g. --mesh 2x4; the "
                         "pipeline then runs inside this mesh context")
    ap.add_argument("--calib-sharded", action="store_true",
                    help="shard the calibration statistics over --mesh: "
                         "per-unit covariance/Gram blocks column-sharded "
                         "over the model axis, batch contributions "
                         "psum-reduced — no device holds a full Sigma "
                         "(requires --mesh)")
    ap.add_argument("--calib-ckpt", default=None,
                    help="directory for resumable calibration-statistics "
                         "checkpoints (CalibrationEngine accumulator is "
                         "saved every --calib-ckpt-every batches and the "
                         "pass resumes from the newest valid one)")
    ap.add_argument("--calib-ckpt-every", type=int, default=8,
                    help="batches between calibration checkpoints")
    ap.add_argument("--one-traversal", action="store_true",
                    help="fuse the two calibration passes into ONE "
                         "traversal: pass 1 speculatively accumulates "
                         "pass-2 ridge statistics for top-k candidate "
                         "keep-sets; units whose final keep-set lands "
                         "inside the candidates need no second pass "
                         "(misses fall back to a targeted mini pass 2 — "
                         "see docs/pipeline.md)")
    ap.add_argument("--spec-margin", type=float, default=0.25,
                    help="candidate safety margin for --one-traversal: "
                         "keep_n * margin extra speculative slots per kv "
                         "group (higher = better hit-rate, more "
                         "accumulator memory — (1+margin)^4 for class-1 "
                         "attention)")
    ap.add_argument("--stats-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="dtype activation taps are STREAMED in during "
                         "calibration (bfloat16 halves calibration HBM "
                         "traffic; every statistic still accumulates fp32)")
    ap.add_argument("--gram-tiles", default=None,
                    help="pin the gram kernel tile sizes as 'BF,BN' (e.g. "
                         "128,512) instead of the per-shape roofline "
                         "autotuner (repro.kernels.gram.autotune)")
    args = ap.parse_args()
    if args.calib_sharded and not args.mesh:
        ap.error("--calib-sharded requires --mesh")
    if args.gram_tiles:
        try:
            bf, bn = (int(v) for v in args.gram_tiles.split(","))
        except ValueError:
            ap.error(f"--gram-tiles must be 'BF,BN' ints, "
                     f"got {args.gram_tiles!r}")
        os.environ["REPRO_GRAM_TILES"] = f"{bf},{bn}"

    cfg = resolve_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_in:
        last = latest_step(args.ckpt_in)
        assert last is not None, f"no checkpoint in {args.ckpt_in}"
        # train checkpoints hold (params, opt_state); restore params only
        (params, _opt), _ = restore_checkpoint(args.ckpt_in, last,
                                               (params, None))
        print(f"[prune] loaded step {last} from {args.ckpt_in}")

    pc = PruneConfig(
        mlp_sparsity=(args.mlp_sparsity if args.mlp_sparsity is not None
                      else args.sparsity),
        attn_sparsity=(args.attn_sparsity if args.attn_sparsity is not None
                       else args.sparsity),
        expert_sparsity=args.expert_sparsity,
        lam=args.lam,
        rank_policy=args.rank_policy,
        compensate=not args.no_compensate,
        round_to=args.round_to,
    )
    stream = calib_stream(cfg, n_samples=args.calib,
                          batch=args.calib_batch, seq=args.calib_seq)

    ctx = make_mesh(parse_shape(args.mesh)) if args.mesh else None
    t0 = time.time()
    kw = dict(progress=print, ckpt_dir=args.calib_ckpt,
              ckpt_every=args.calib_ckpt_every,
              mesh=ctx if args.calib_sharded else None,
              stats_dtype=args.stats_dtype,
              one_traversal=args.one_traversal,
              spec_margin=args.spec_margin)
    if ctx is not None:
        with ctx:
            new_params, new_cfg, report = corp_prune(model, params, stream,
                                                     pc, **kw)
    else:
        new_params, new_cfg, report = corp_prune(model, params, stream, pc,
                                                 **kw)
    dt = time.time() - t0
    print(f"[prune] done in {dt:.1f}s; "
          f"d_ff {cfg.d_ff} -> {new_cfg.eff_d_ff}, "
          f"qk {cfg.qk_full} -> {new_cfg.eff_qk}"
          + (f", experts {cfg.moe.num_experts} -> "
             f"{new_cfg.eff_num_experts}" if cfg.moe is not None else ""))
    if "speculative" in report:
        sp = report["speculative"]
        print(f"[prune] one-traversal: {report['traversals']} traversal(s), "
              f"margin {sp['margin']}, {len(sp['hits'])} hit / "
              f"{len(sp['misses'])} miss"
              + (f" (re-passed: {', '.join(sp['misses'])})"
                 if sp["misses"] else ""))

    if args.out:
        save_checkpoint(args.out, 0, new_params,
                        extra={"config": new_cfg.name,
                               "mlp_sparsity": pc.mlp_sparsity,
                               "attn_sparsity": pc.attn_sparsity,
                               "expert_sparsity": pc.expert_sparsity})
        with open(f"{args.out}/report.json", "w") as f:
            # stacked-layer units report per-layer diagnostic arrays
            json.dump(jax.tree.map(
                lambda x: x.tolist() if hasattr(x, "tolist") else x,
                report["units"]), f, indent=1, default=str)
        print(f"[prune] saved to {args.out}")


if __name__ == "__main__":
    main()
