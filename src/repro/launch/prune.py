"""Distributed CORP pruning driver.

    PYTHONPATH=src python -m repro.launch.prune --arch deit-tiny-reduced \
        --sparsity 0.5 --calib 256 --ckpt-in /tmp/ckpt --out /tmp/pruned

Loads (or initializes) dense params, runs the one-shot CORP pipeline over a
calibration stream, saves the pruned checkpoint + report. With --mesh the
statistics passes run under pjit on the production mesh (the reductions
compile to psums over the data axes).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core import PruneConfig, corp_prune
from repro.data import calib_stream
from repro.launch.mesh import make_mesh
from repro.launch.train import resolve_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--mlp-sparsity", type=float, default=None)
    ap.add_argument("--attn-sparsity", type=float, default=None)
    ap.add_argument("--calib", type=int, default=128)
    ap.add_argument("--calib-batch", type=int, default=8)
    ap.add_argument("--calib-seq", type=int, default=64)
    ap.add_argument("--rank-policy", default="combined")
    ap.add_argument("--no-compensate", action="store_true")
    ap.add_argument("--round-to", type=int, default=1)
    ap.add_argument("--lam", type=float, default=1e-4)
    ap.add_argument("--ckpt-in", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--calib-ckpt", default=None,
                    help="directory for resumable calibration-statistics "
                         "checkpoints (CalibrationEngine accumulator is "
                         "saved every --calib-ckpt-every batches and the "
                         "pass resumes from the newest valid one)")
    ap.add_argument("--calib-ckpt-every", type=int, default=8)
    args = ap.parse_args()

    cfg = resolve_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_in:
        last = latest_step(args.ckpt_in)
        assert last is not None, f"no checkpoint in {args.ckpt_in}"
        # train checkpoints hold (params, opt_state); restore params only
        (params, _opt), _ = restore_checkpoint(args.ckpt_in, last,
                                               (params, None))
        print(f"[prune] loaded step {last} from {args.ckpt_in}")

    pc = PruneConfig(
        mlp_sparsity=(args.mlp_sparsity if args.mlp_sparsity is not None
                      else args.sparsity),
        attn_sparsity=(args.attn_sparsity if args.attn_sparsity is not None
                       else args.sparsity),
        lam=args.lam,
        rank_policy=args.rank_policy,
        compensate=not args.no_compensate,
        round_to=args.round_to,
    )
    stream = calib_stream(cfg, n_samples=args.calib,
                          batch=args.calib_batch, seq=args.calib_seq)

    ctx = make_mesh(tuple(int(x) for x in args.mesh.split("x"))) \
        if args.mesh else None
    t0 = time.time()
    kw = dict(progress=print, ckpt_dir=args.calib_ckpt,
              ckpt_every=args.calib_ckpt_every)
    if ctx is not None:
        with ctx:
            new_params, new_cfg, report = corp_prune(model, params, stream,
                                                     pc, **kw)
    else:
        new_params, new_cfg, report = corp_prune(model, params, stream, pc,
                                                 **kw)
    dt = time.time() - t0
    print(f"[prune] done in {dt:.1f}s; "
          f"d_ff {cfg.d_ff} -> {new_cfg.eff_d_ff}, "
          f"qk {cfg.qk_full} -> {new_cfg.eff_qk}")

    if args.out:
        save_checkpoint(args.out, 0, new_params,
                        extra={"config": new_cfg.name,
                               "mlp_sparsity": pc.mlp_sparsity,
                               "attn_sparsity": pc.attn_sparsity})
        with open(f"{args.out}/report.json", "w") as f:
            # stacked-layer units report per-layer diagnostic arrays
            json.dump(jax.tree.map(
                lambda x: x.tolist() if hasattr(x, "tolist") else x,
                report["units"]), f, indent=1, default=str)
        print(f"[prune] saved to {args.out}")


if __name__ == "__main__":
    main()
