import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/roofline terms.

MUST be run as its own process (the device-count flag is set above, before
any other import, because jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --out dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh single

The run is restartable: one JSON record per cell, existing cells skipped.
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config     # noqa: E402
from repro.distrib import sharding as shard_mod             # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models import build_model, cache_specs, input_specs  # noqa: E402
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine  # noqa: E402
from repro.roofline import analyze_compiled, model_flops, params_count  # noqa: E402


def should_skip(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("pure full-attention arch: 500k decode skipped per "
                "assignment (see DESIGN.md §3.1)")
    return None


def _named(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def cache_partition_specs(cache_sds, mesh, global_batch: int):
    """Heuristic decode-cache sharding: the *batch* dim (size ==
    global_batch; scanned caches carry a leading reps dim, so it is not
    always dim 0) over ('pod','data'); the largest remaining model-divisible
    dim over 'model' (time axis for KV — flash-decoding style partial
    softmax; inner dim for SSM states)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = tuple(a for a in ("pod", "data") if a in sizes)
    bsize = int(np.prod([sizes[a] for a in baxes]))
    msize = sizes.get("model", 1)

    def f(x):
        spec = [None] * x.ndim
        bdim = None
        for i, s in enumerate(x.shape):
            if s == global_batch and s % bsize == 0:
                bdim = i
                break
        if bdim is not None:
            spec[bdim] = baxes if len(baxes) > 1 else baxes[0]
        cands = sorted((i for i in range(x.ndim) if i != bdim),
                       key=lambda i: -x.shape[i])
        for i in cands:
            if x.shape[i] % msize == 0 and x.shape[i] >= msize:
                spec[i] = "model"
                break
        return P(*spec)
    return jax.tree.map(f, cache_sds)


def build_lowering(arch: str, shape_name: str, mesh, *, sparsity=0.0):
    cfg = get_config(arch)
    if sparsity > 0:
        cfg = cfg.pruned(sparsity, sparsity)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    pc = params_count(cfg)
    fsdp = pc["total"] * 2 / dict(zip(mesh.axis_names, mesh.devices.shape)) \
        .get("model", 1) > 2e9
    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = shard_mod.param_specs(params_sds, mesh, fsdp=fsdp)
    pshard = _named(pspecs, mesh)
    batch_sds = input_specs(cfg, shape)
    bshard = _named(shard_mod.batch_specs(batch_sds, mesh), mesh)
    seq_ok = shape.seq_len % dict(zip(mesh.axis_names,
                                      mesh.devices.shape)).get("model", 1) == 0
    # sequence-parallel residual except for mamba stacks, whose chunked
    # selective scan forces a reshard around every recurrent layer (§Perf
    # J1; rwkv's chunked wkv tolerates a seq-sharded residual — measured)
    has_mamba = any(k == "mamba" for k in cfg.layer_kinds)
    seq_shard = shape.kind != "decode" and seq_ok and not has_mamba
    if shape.kind == "prefill" and cfg.mla is None and cfg.has_attention:
        # prefill-SP trades the (B,T,D) output all-reduce for a per-layer
        # K/V all-gather of (B,S,Hkv,dq): only a win when KV is compressed
        # vs the residual width (GQA/MLA), a wash or loss for plain MHA
        # (measured on deepseek-7b: tx x1.76) — §Perf D1 refinement
        seq_shard = seq_shard and cfg.n_kv_heads * cfg.qk_full < cfg.d_model
    rules = shard_mod.make_activation_rules(
        batch_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names),
        seq_shard=seq_shard)
    if cfg.mla is None:
        # head-sharded qkv only pays off for MLA's per-head K expansion;
        # for plain MHA/GQA GSPMD's own schedule measured better (§Perf D2)
        rules = dict(rules, attn_qkv=None)

    if shape.kind == "train":
        ocfg = AdamWConfig(m_dtype="bfloat16" if pc["total"] > 1e11
                           else "float32")
        opt_sds = jax.eval_shape(lambda: adamw_init(params_sds, ocfg))
        oshard = _named(shard_mod.param_specs(opt_sds, mesh, fsdp=fsdp), mesh)
        micro = int(os.environ.get("REPRO_MICROBATCH", "1"))

        def train_step(params, opt_state, batch):
            if micro > 1:
                # gradient accumulation (§Perf iteration J3): same global
                # batch, `micro` sequential microbatches — divides the
                # activation-transient memory by `micro` at the cost of
                # re-gathering FSDP weights per microstep
                mb = jax.tree.map(
                    lambda a: a.reshape((micro, a.shape[0] // micro)
                                        + a.shape[1:]), batch)

                acc_dt = jnp.dtype(os.environ.get("REPRO_GACC_DTYPE",
                                                  "float32"))

                def micro_step(acc, b):
                    loss, grads = jax.value_and_grad(
                        lambda p: model.loss(p, b))(params)
                    grads = jax.tree.map(lambda g: g.astype(acc_dt), grads)
                    return jax.tree.map(jnp.add, acc,
                                        (grads, loss.astype(acc_dt))), None

                zero = (jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                     params), jnp.zeros((), acc_dt))
                (gsum, lsum), _ = jax.lax.scan(micro_step, zero, mb)
                grads = jax.tree.map(lambda g: g / micro, gsum)
                loss = lsum / micro
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: model.loss(p, batch))(params)
            lr = warmup_cosine(opt_state["step"], peak=3e-4, warmup=2000,
                               total=100_000)
            new_p, new_o, metrics = adamw_update(params, grads, opt_state,
                                                 lr, ocfg)
            return new_p, new_o, loss

        # donate params+opt: the update aliases them in place (halves the
        # resident state vs keeping old+new live across the step)
        fn = jax.jit(train_step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch, shape.seq_len)
        fn = jax.jit(prefill_step, in_shardings=(pshard, bshard))
        args = (params_sds, batch_sds)
    else:  # decode
        c_sds = cache_specs(cfg, shape)
        cshard = _named(cache_partition_specs(c_sds, mesh,
                                              shape.global_batch), mesh)
        tok_sds = batch_sds["token"]

        def decode(params, token, cache):
            return model.decode_step(params, token, cache)

        # donate the cache: decode updates it in place (no double-resident
        # KV, and the scatter aliases instead of copying)
        fn = jax.jit(decode, in_shardings=(pshard, None, cshard),
                     out_shardings=(None, cshard), donate_argnums=(2,))
        args = (params_sds, tok_sds, c_sds)

    with mesh:
        with shard_mod.activation_policy(rules, mesh=mesh):
            lowered = fn.lower(*args)
            from repro.roofline.analysis import jaxpr_matmul_flops
            logical_flops = jaxpr_matmul_flops(fn, *args)
    return lowered, cfg, shape, logical_flops


def run_cell(arch, shape_name, mesh_kind, *, sparsity=0.0):
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "sparsity": sparsity}
    cfg = get_config(arch)
    if sparsity > 0:
        cfg = cfg.pruned(sparsity, sparsity)
    shape = SHAPES[shape_name]
    skip = should_skip(cfg, shape)
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        t0 = time.time()
        lowered, cfg, shape, lflops = build_lowering(arch, shape_name, mesh,
                                                     sparsity=sparsity)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        with mesh:
            compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)
        n_dev = int(mesh.devices.size)
        terms = analyze_compiled(compiled, n_devices=n_dev,
                                 logical_flops=lflops)
        mf = model_flops(cfg, shape)
        rec.update(status="ok", roofline=terms,
                   model_flops=mf, logical_flops=lflops,
                   useful_flops_ratio=mf / max(lflops, 1.0),
                   params=params_count(cfg))
    except Exception as e:   # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--sparsity", type=float, default=0.0,
                    help="CORP sparsity for pruned-model dry-runs")
    ap.add_argument("--out", default="dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    records = {}
    if os.path.exists(args.out):
        for r in json.load(open(args.out)):
            records[(r["arch"], r["shape"], r["mesh"],
                     r.get("sparsity", 0.0))] = r

    def flush():
        tmp = args.out + ".tmp"
        with open(tmp, "w") as f:
            json.dump(list(records.values()), f, indent=1)
        os.replace(tmp, args.out)

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                key = (arch, shape, mk, args.sparsity)
                if key in records and not args.force \
                        and records[key]["status"] in ("ok", "skipped"):
                    continue
                print(f"[dryrun] {arch} x {shape} x {mk} ...", flush=True)
                rec = run_cell(arch, shape, mk, sparsity=args.sparsity)
                records[key] = rec
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" tc={r['t_compute']:.3e}"
                             f" tm={r['t_memory']:.3e}"
                             f" tx={r['t_collective']:.3e}")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"[dryrun] {arch} x {shape} x {mk}: {status}{extra}",
                      flush=True)
                flush()
    flush()
    n_ok = sum(1 for r in records.values() if r["status"] == "ok")
    n_err = sum(1 for r in records.values() if r["status"] == "error")
    n_skip = sum(1 for r in records.values() if r["status"] == "skipped")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
