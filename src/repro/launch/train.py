"""Training driver with checkpoint/restart, mesh sharding and logging.

Examples (CPU-sized):
    PYTHONPATH=src python -m repro.launch.train --arch deit-tiny-reduced \
        --steps 200 --batch 32 --ckpt /tmp/ckpt
    PYTHONPATH=src python -m repro.launch.train --arch granite-8b-reduced \
        --steps 50 --batch 8 --seq 64 --mesh 1x1

On a real cluster the same entry point runs with --mesh 16x16 (or 2x16x16)
and the full config names; everything below is mesh-size agnostic.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import get_config, reduced
from repro.data import lm_batch, vit_batch
from repro.distrib import sharding as shard_mod
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


def resolve_config(name: str):
    if name.endswith("-reduced"):
        return reduced(get_config(name[: -len("-reduced")]))
    return get_config(name)


def make_train_step(model, ocfg, *, peak_lr, total_steps):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        lr = warmup_cosine(opt_state["step"], peak=peak_lr,
                           warmup=max(10, total_steps // 20),
                           total=total_steps)
        new_p, new_o, metrics = adamw_update(params, grads, opt_state, lr,
                                             ocfg)
        return new_p, new_o, loss, metrics["grad_norm"]
    return train_step


def data_for(cfg, step, *, batch, seq, seed=0):
    if cfg.family == "vit":
        return vit_batch(step, batch=batch, img=cfg.img_size,
                         n_classes=max(2, cfg.n_classes), seed=seed)
    b = lm_batch(step, batch=batch, seq=seq, vocab=cfg.vocab_size, seed=seed)
    if cfg.family == "encdec":
        rng = np.random.RandomState(seed * 77 + step)
        b = dict(b, frames=jnp.asarray(
            rng.randn(batch, seq, cfg.d_model).astype(np.float32)))
    if cfg.frontend == "patch_stub":
        rng = np.random.RandomState(seed * 79 + step)
        b = dict(b, patch_embeds=jnp.asarray(
            rng.randn(batch, 8, cfg.d_model).astype(np.float32)))
    return b


def train(cfg, *, steps, batch, seq, ckpt_dir=None, mesh_shape=None,
          peak_lr=3e-4, save_every=50, log_every=10, seed=0,
          fsdp=False, log=print):
    model = build_model(cfg)
    ocfg = AdamWConfig()
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params, ocfg)
    step0 = 0

    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), extra = restore_checkpoint(
                ckpt_dir, last, (params, opt_state))
            step0 = last
            log(f"[train] resumed from step {last}")

    step_fn = make_train_step(model, ocfg, peak_lr=peak_lr,
                              total_steps=steps)
    mesh = None
    if mesh_shape:
        mesh = make_mesh(mesh_shape)
        pspecs = shard_mod.param_specs(params, mesh, fsdp=fsdp)
        pshard = shard_mod.shardings_of(pspecs, mesh)
        oshard = shard_mod.shardings_of(
            shard_mod.param_specs(opt_state, mesh, fsdp=fsdp), mesh)
        jit_step = jax.jit(step_fn, in_shardings=(pshard, oshard, None),
                           out_shardings=(pshard, oshard, None, None))
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)
    else:
        jit_step = jax.jit(step_fn)

    losses = []
    t0 = time.time()
    ctx = mesh or _nullcontext()
    with ctx:
        for step in range(step0, steps):
            b = data_for(cfg, step, batch=batch, seq=seq, seed=seed)
            params, opt_state, loss, gn = jit_step(params, opt_state, b)
            losses.append(float(loss))
            if (step + 1) % log_every == 0:
                dt = (time.time() - t0) / log_every
                log(f"[train] step {step+1}/{steps} loss {float(loss):.4f} "
                    f"gnorm {float(gn):.3f} {dt*1e3:.0f} ms/step")
                t0 = time.time()
            if ckpt and ((step + 1) % save_every == 0 or step + 1 == steps):
                ckpt.save(step + 1, (params, opt_state))
    if ckpt:
        ckpt.wait()
    return params, opt_state, losses


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="config id; append '-reduced' for the CPU-size variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x2 (axes data,model) or 2x4x4 (pod,data,model)")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = resolve_config(args.arch)
    mesh_shape = tuple(int(x) for x in args.mesh.split("x")) if args.mesh \
        else None
    _, _, losses = train(cfg, steps=args.steps, batch=args.batch,
                         seq=args.seq, ckpt_dir=args.ckpt,
                         mesh_shape=mesh_shape, peak_lr=args.lr,
                         save_every=args.save_every, fsdp=args.fsdp,
                         seed=args.seed)
    print(f"[train] final loss {losses[-1]:.4f} "
          f"(first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
