"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run entry
point must set XLA_FLAGS before any jax initialization. The same constraint
is why ``force_host_devices`` exists: simulated multi-device runs (tests,
docs examples, the sharded-calibration benchmark) must set
``--xla_force_host_platform_device_count`` before the first jax import in
the process.
"""
from __future__ import annotations

import os


def force_host_devices(n: int):
    """Simulate ``n`` devices on the host CPU platform.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``.
    Must run BEFORE jax initializes its backends (i.e. before the first
    ``import jax`` in the process, or at least before any jax API touches
    devices) — raises if jax is already initialized. This is how the
    sharded-calibration tests and ``benchmarks/bench_calib_sharded.py``
    build a >=4-device mesh on a laptop.
    """
    import sys
    jx = sys.modules.get("jax")
    try:
        initialized = bool(jx._src.xla_bridge._backends)  # type: ignore
    except AttributeError:
        initialized = False
    if initialized:
        raise RuntimeError(
            "force_host_devices must be called before jax initializes "
            "its backends; set XLA_FLAGS in the environment instead")
    flag = f"--xla_force_host_platform_device_count={n}"
    prev = os.environ.get("XLA_FLAGS", "")
    if flag not in prev:
        os.environ["XLA_FLAGS"] = (prev + " " + flag).strip()


def make_production_mesh(*, multi_pod: bool = False):
    """The fleet meshes: (data=16, model=16) per pod, x2 pods multi-pod."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def parse_shape(spec: str):
    """Parse a ``'DxM'`` mesh-shape string: ``'2x2'`` -> ``(2, 2)``.

    The CLI grammar shared by ``repro.launch.prune --mesh`` and
    ``repro.launch.serve --mesh-shape`` (axis names come from
    ``make_mesh``'s defaults: trailing names of ('pod','data','model'))."""
    return tuple(int(x) for x in spec.lower().split("x"))


def make_mesh(shape, axes=None):
    """Arbitrary mesh for tests/examples (e.g. (2,4) on 8 host devices).

    Args:
      shape: device-grid shape, e.g. ``(2, 4)`` = 2-way data x 4-way model.
      axes: axis names; defaults to the trailing names of
        ('pod', 'data', 'model') matching ``len(shape)``.
    """
    import jax
    if axes is None:
        axes = ("data", "model")[-len(shape):] if len(shape) <= 2 \
            else ("pod", "data", "model")
    return jax.make_mesh(tuple(shape), tuple(axes))
