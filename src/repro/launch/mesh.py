"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run entry
point must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes=None):
    """Arbitrary mesh for tests/examples (e.g. (2,4) on 8 host devices)."""
    if axes is None:
        axes = ("data", "model")[-len(shape):] if len(shape) <= 2 \
            else ("pod", "data", "model")
    return jax.make_mesh(tuple(shape), tuple(axes))
