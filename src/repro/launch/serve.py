"""Serving driver: fixed-batch baseline loop + continuous-batching engine.

Fixed-batch (the pre-engine baseline, kept for comparison):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b-reduced \
        --batch 4 --prompt-len 32 --gen 16

Continuous batching over a synthetic ragged arrival trace (reports p50/p99
per-request latency and aggregate tok/s — see docs/serving.md):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b-reduced \
        --trace 24 --slots 4 --max-len 128 --compare-static

With --ckpt-in it serves a pruned checkpoint produced by repro.launch.prune
(pass --sparsity to match); pruned configs shrink the KV cache automatically.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.launch.train import resolve_config
from repro.models import build_model


def serve_loop(model, params, *, batch, prompt_len, gen, max_len,
               seed=0, log=print):
    """Fixed-batch prefill + greedy decode; returns exactly ``gen`` tokens
    per request: the prefill argmax plus ``gen - 1`` decode steps, each of
    which is inside the timed region (the old loop ran one extra decode step
    whose token was discarded, so the stream was shifted off the timing)."""
    cfg = model.cfg
    rng = np.random.RandomState(seed)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size,
                                   size=(batch, prompt_len)), jnp.int32)
    req = {"tokens": toks}
    if cfg.family == "encdec":
        req["frames"] = jnp.asarray(
            rng.randn(batch, prompt_len, cfg.d_model).astype(np.float32))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode_step)

    def argmax(logits):
        return jnp.argmax(logits[:, -1, : cfg.vocab_size],
                          axis=-1)[:, None].astype(jnp.int32)

    # warm up (compile) outside the timed region
    logits, cache = prefill(params, req)
    _l, _c = decode(params, argmax(logits), cache)
    jax.block_until_ready(_l)

    t0 = time.time()
    logits, cache = prefill(params, req)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = argmax(logits)          # first generated token (from prefill)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = argmax(logits)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    steps = gen - 1
    log(f"[serve] prefill {t_prefill*1e3:.1f} ms "
        f"({batch}x{prompt_len} tokens); decode "
        f"{steps} steps in {t_decode*1e3:.1f} ms -> "
        f"{batch*steps/max(t_decode,1e-9):.1f} tok/s")
    return jnp.concatenate(out_tokens, axis=1), t_prefill, t_decode


def serve_trace(model, params, *, n, slots, max_len, prompt_range, gen_range,
                rate=None, seed=0, compare_static=False, log=print):
    """Continuous-batching engine over a synthetic ragged trace."""
    from repro.serve import (ServeEngine, percentile_table, run_static_trace,
                             synthetic_trace)
    from repro.serve.engine import format_table
    cfg = model.cfg
    trace = synthetic_trace(n, cfg.vocab_size, seed=seed,
                            prompt_range=prompt_range, gen_range=gen_range,
                            rate=rate)
    eng = ServeEngine(model, params, n_slots=slots, max_len=max_len)
    eng.warmup(prompt_lens=[len(r.tokens) for r in trace])
    t0 = time.perf_counter()
    comps = eng.run(trace)
    wall = time.perf_counter() - t0
    table = percentile_table(comps, wall)
    table["mode"] = "continuous"
    rows = [table]
    log(f"[serve] continuous: {eng.stats['admits']} admits, "
        f"{eng.stats['decode_steps']} decode steps, "
        f"lane utilization "
        f"{eng.stats['decode_lanes'] / max(1, eng.stats['decode_steps'] * slots):.0%}, "
        f"cache {eng.cache_bytes / 1e6:.2f} MB")
    if compare_static:
        # run_static_trace compile-warms internally; time from its clock
        comps_s = run_static_trace(model, params, trace, n_slots=slots,
                                   max_len=max_len)
        ts = percentile_table(comps_s, max(c.t_done for c in comps_s))
        ts["mode"] = "static"
        rows.append(ts)
    keys = ["mode", "requests", "tokens", "tok_per_s", "lat_p50_ms",
            "lat_p99_ms", "ttft_p50_ms", "ttft_p99_ms"]
    log(format_table(rows, keys))
    return comps, table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--ckpt-in", default=None)
    ap.add_argument("--trace", type=int, default=0,
                    help="serve N synthetic ragged requests through the "
                         "continuous-batching engine instead of the "
                         "fixed-batch loop")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine slots (concurrent requests)")
    ap.add_argument("--max-len", type=int, default=128,
                    help="per-slot sequence budget (prompt + gen)")
    ap.add_argument("--prompt-range", default="8,48",
                    help="trace prompt lengths, 'lo,hi'")
    ap.add_argument("--gen-range", default="4,48",
                    help="trace generation lengths, 'lo,hi'")
    ap.add_argument("--rate", type=float, default=None,
                    help="trace arrival rate (req/s); default all at t=0")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare-static", action="store_true",
                    help="also run the fixed-batch baseline on the same "
                         "trace and print both rows")
    args = ap.parse_args()

    cfg = resolve_config(args.arch)
    if args.sparsity > 0:
        cfg = cfg.pruned(args.sparsity, args.sparsity)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_in:
        last = latest_step(args.ckpt_in)
        params, _ = restore_checkpoint(args.ckpt_in, last, params)
        print(f"[serve] loaded {args.ckpt_in} step {last}")
    if args.trace > 0:
        pr = tuple(int(x) for x in args.prompt_range.split(","))
        gr = tuple(int(x) for x in args.gen_range.split(","))
        serve_trace(model, params, n=args.trace, slots=args.slots,
                    max_len=args.max_len, prompt_range=pr, gen_range=gr,
                    rate=args.rate, seed=args.seed,
                    compare_static=args.compare_static)
    else:
        serve_loop(model, params, batch=args.batch,
                   prompt_len=args.prompt_len, gen=args.gen,
                   max_len=args.prompt_len + args.gen + 1)


if __name__ == "__main__":
    main()
