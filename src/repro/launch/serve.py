"""Serving driver: fixed-batch baseline loop + continuous-batching engine.

Fixed-batch (the pre-engine baseline, kept for comparison):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b-reduced \
        --batch 4 --prompt-len 32 --gen 16

Continuous batching over a synthetic ragged arrival trace, driven through
the async front-end (bounded queue, deadlines, prefix cache — reports
per-status counts plus p50/p99 latency/ttft; see docs/serving.md):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b-reduced \
        --trace 24 --slots 4 --max-len 128 --compare-static

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b-reduced \
        --trace 32 --slots 4 --rate 20 --queue-depth 8 \
        --deadline-ms 200,800 --deadline-frac 0.5 \
        --prefix-cache 8 --prefix-len 24

With --ckpt-in it serves a pruned checkpoint produced by repro.launch.prune
(pass --sparsity to match); pruned configs shrink the KV cache automatically.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.launch.train import resolve_config
from repro.models import build_model


def serve_loop(model, params, *, batch, prompt_len, gen, max_len,
               seed=0, log=print):
    """Fixed-batch prefill + greedy decode; returns exactly ``gen`` tokens
    per request: the prefill argmax plus ``gen - 1`` decode steps, each of
    which is inside the timed region (the old loop ran one extra decode step
    whose token was discarded, so the stream was shifted off the timing)."""
    cfg = model.cfg
    rng = np.random.RandomState(seed)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size,
                                   size=(batch, prompt_len)), jnp.int32)
    req = {"tokens": toks}
    if cfg.family == "encdec":
        req["frames"] = jnp.asarray(
            rng.randn(batch, prompt_len, cfg.d_model).astype(np.float32))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode_step)

    def argmax(logits):
        return jnp.argmax(logits[:, -1, : cfg.vocab_size],
                          axis=-1)[:, None].astype(jnp.int32)

    # warm up (compile) outside the timed region
    logits, cache = prefill(params, req)
    _l, _c = decode(params, argmax(logits), cache)
    jax.block_until_ready(_l)

    t0 = time.time()
    logits, cache = prefill(params, req)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = argmax(logits)          # first generated token (from prefill)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = argmax(logits)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    steps = gen - 1
    log(f"[serve] prefill {t_prefill*1e3:.1f} ms "
        f"({batch}x{prompt_len} tokens); decode "
        f"{steps} steps in {t_decode*1e3:.1f} ms -> "
        f"{batch*steps/max(t_decode,1e-9):.1f} tok/s")
    return jnp.concatenate(out_tokens, axis=1), t_prefill, t_decode


def serve_trace(model, params, *, n, slots, max_len, prompt_range, gen_range,
                rate=None, seed=0, compare_static=False, queue_depth=16,
                deadline_ms=None, deadline_frac=1.0, prefix_cache=0,
                prefix_len=0, spf=False, replicas=1, route="least-loaded",
                mem_len=None, sharding=None, prefill_chunk=None, log=print):
    """Async front-end + continuous-batching engine over a synthetic trace.

    The trace drives the full serving stack: Poisson arrivals (``rate``),
    a deadline mix (``deadline_ms`` range hits ``deadline_frac`` of the
    requests), bounded-queue admission (``queue_depth``, FIFO or
    shortest-prompt-first), and optional prefix-cache reuse of a shared
    ``prefix_len``-token system prompt. Overload surfaces as typed
    rejections in the table, never as a deadlock.

    With ``replicas > 1`` the trace is served by a fleet of engines behind
    a :class:`~repro.serve.ReplicaRouter` (``route`` picks the policy);
    the front-end layers on the router exactly as it layers on one engine.
    Prefix caching in routed mode is per-replica and owned by the router
    (``route=prefix-affinity``); the front-end's shared cache is
    single-engine only.

    With a ``sharding`` (``repro.serve.ServeSharding``, built from
    ``--mesh-shape``/``--serve-sharded``) each engine's decode step runs
    under pjit with the slot cache model-sharded; the report additionally
    logs the per-device cache footprint (docs/serving.md "Mesh-sharded
    serving").

    With ``prefill_chunk`` set, cold admits prefill at most that many
    prompt tokens per engine iteration (docs/serving.md "Scheduler"):
    occupied slots take a decode step between chunks, so long prompts
    never freeze co-resident streams; token output is byte-identical.
    """
    from repro.serve import (PrefixCache, ReplicaRouter, ServeEngine,
                             ServeFrontend, frontend_table,
                             percentile_table, run_static_trace,
                             synthetic_trace)
    from repro.serve.engine import format_table
    cfg = model.cfg
    dl_range = None if deadline_ms is None else \
        tuple(x / 1e3 for x in deadline_ms)
    trace = synthetic_trace(n, cfg.vocab_size, seed=seed,
                            prompt_range=prompt_range, gen_range=gen_range,
                            rate=rate, deadline_range=dl_range,
                            deadline_frac=deadline_frac,
                            prefix_len=prefix_len, mem_len=mem_len,
                            d_model=cfg.d_model)
    engines = [ServeEngine(model, params, n_slots=slots, max_len=max_len,
                           mem_len=mem_len, sharding=sharding)
               for _ in range(max(1, replicas))]
    for e in engines:
        e.warmup(prompt_lens=[len(r.tokens) for r in trace],
                 prefix=prefix_cache > 0, prefill_chunk=prefill_chunk)
    if replicas > 1:
        eng = ReplicaRouter(engines, route=route, prefix_cap=prefix_cache)
        pc = None
    else:
        eng = engines[0]
        pc = PrefixCache(cap=prefix_cache) if prefix_cache > 0 else None
    fe = ServeFrontend(eng, queue_depth=queue_depth,
                       policy="spf" if spf else "fifo", prefix_cache=pc,
                       prefill_chunk=prefill_chunk)
    t0 = time.perf_counter()
    handles = fe.run(trace, log=log)
    wall = time.perf_counter() - t0
    table = frontend_table(handles, wall)
    table["mode"] = f"fleet-x{replicas}" if replicas > 1 else "frontend"
    rows = [table]
    log(f"[serve] frontend: {eng.stats['admits']} admits, "
        f"{eng.stats['decode_steps']} decode steps, "
        f"lane utilization "
        f"{eng.stats['decode_lanes'] / max(1, eng.stats['decode_steps'] * slots):.0%}, "
        f"cache {eng.cache_bytes / 1e6:.2f} MB")
    if sharding is not None:
        e0 = engines[0]
        log(f"[serve] sharded over {dict(sharding.sizes)}: per-device "
            f"cache {e0.device_cache_bytes / 1e6:.2f} MB "
            f"({e0.cache_bytes / max(e0.device_cache_bytes, 1):.2f}x "
            f"smaller than unsharded)")
    if replicas > 1:
        log(f"[serve] router: {dict(eng.rstats)}; "
            f"states {[s.value for s in eng.states]}")
        if eng.prefix_stats() is not None:
            for i, st in enumerate(eng.prefix_stats()):
                log(f"[serve] replica {i} prefix cache: {st}")
    if pc is not None:
        log(f"[serve] prefix cache: {pc.stats()}")
    if compare_static:
        # run_static_trace compile-warms internally; time from its clock
        comps_s = run_static_trace(model, params, trace, n_slots=slots,
                                   max_len=max_len)
        ts = percentile_table(comps_s, max(c.t_done for c in comps_s))
        ts["mode"] = "static"
        rows.append(ts)
    keys = ["mode", "requests", "done", "rejected", "expired", "failed",
            "tokens", "tok_per_s", "lat_p50_ms", "lat_p99_ms",
            "ttft_p50_ms", "ttft_p99_ms"]
    log(format_table(rows, keys))
    return handles, table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--expert-sparsity", type=float, default=0.0,
                    help="serve with a fraction of routed experts removed "
                         "(MoE archs; mirrors repro.launch.prune)")
    ap.add_argument("--mem-len", type=int, default=None,
                    help="enc-dec only: fixed encoder-memory length; trace "
                         "requests carry synthetic frames of this length")
    ap.add_argument("--ckpt-in", default=None)
    ap.add_argument("--trace", type=int, default=0,
                    help="serve N synthetic ragged requests through the "
                         "continuous-batching engine instead of the "
                         "fixed-batch loop")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine slots (concurrent requests)")
    ap.add_argument("--max-len", type=int, default=128,
                    help="per-slot sequence budget (prompt + gen)")
    ap.add_argument("--prompt-range", default="8,48",
                    help="trace prompt lengths, 'lo,hi'")
    ap.add_argument("--gen-range", default="4,48",
                    help="trace generation lengths, 'lo,hi'")
    ap.add_argument("--rate", type=float, default=None,
                    help="trace arrival rate (req/s); default all at t=0")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare-static", action="store_true",
                    help="also run the fixed-batch baseline on the same "
                         "trace and print both rows")
    ap.add_argument("--queue-depth", type=int, default=16,
                    help="bounded admission queue beyond the slots; "
                         "requests past it are rejected (backpressure)")
    ap.add_argument("--deadline-ms", default=None,
                    help="per-request deadline budget, 'lo,hi' ms after "
                         "arrival; expired requests keep partial tokens")
    ap.add_argument("--deadline-frac", type=float, default=1.0,
                    help="fraction of requests given a deadline "
                         "(the deadline mix)")
    ap.add_argument("--prefix-cache", type=int, default=0,
                    help="prefix-cache capacity in entries; 0 disables "
                         "(pure global-attention LMs only)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared system-prompt tokens prepended to every "
                         "trace request (the prefix-cache workload)")
    ap.add_argument("--spf", action="store_true",
                    help="shortest-prompt-first admission instead of FIFO")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="max prompt tokens a cold admit prefills per "
                         "engine iteration (chunked prefill via the "
                         "scheduler); default: atomic whole-prompt admits")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the ReplicaRouter; 1 "
                         "serves through a single engine (no router)")
    ap.add_argument("--route", default="least-loaded",
                    choices=["least-loaded", "prefix-affinity"],
                    help="fleet routing policy: fewest occupied slots, or "
                         "longest cached prefix (per-replica caches; pure "
                         "global-attention LMs only)")
    ap.add_argument("--mesh-shape", default=None,
                    help="device mesh shape 'DxM' (data x model) for "
                         "--serve-sharded, e.g. 2x2; simulated host "
                         "devices are forced to fill it on CPU")
    ap.add_argument("--serve-sharded", action="store_true",
                    help="run the shared decode step under pjit with the "
                         "slot cache model-sharded over --mesh-shape "
                         "(params placed by distrib.sharding.param_specs; "
                         "requires --mesh-shape)")
    args = ap.parse_args()
    if args.serve_sharded and not args.mesh_shape:
        ap.error("--serve-sharded requires --mesh-shape")

    sharding = None
    if args.serve_sharded:
        from repro.launch.mesh import (force_host_devices, make_mesh,
                                       parse_shape)
        from repro.serve import ServeSharding
        shape = parse_shape(args.mesh_shape)
        try:
            # simulated-host story: fill the mesh with forced CPU devices
            # (no-op when XLA_FLAGS already carries the flag)
            force_host_devices(int(np.prod(shape)))
        except RuntimeError:
            pass   # backends already up: respect the ambient device set
        sharding = ServeSharding(make_mesh(shape))

    cfg = resolve_config(args.arch)
    if args.sparsity > 0 or args.expert_sparsity > 0:
        cfg = cfg.pruned(args.sparsity, args.sparsity,
                         expert_sparsity=args.expert_sparsity)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_in:
        last = latest_step(args.ckpt_in)
        params, _ = restore_checkpoint(args.ckpt_in, last, params)
        print(f"[serve] loaded {args.ckpt_in} step {last}")
    if args.trace > 0:
        pr = tuple(int(x) for x in args.prompt_range.split(","))
        gr = tuple(int(x) for x in args.gen_range.split(","))
        dl = None if args.deadline_ms is None else \
            tuple(float(x) for x in args.deadline_ms.split(","))
        serve_trace(model, params, n=args.trace, slots=args.slots,
                    max_len=args.max_len, prompt_range=pr, gen_range=gr,
                    rate=args.rate, seed=args.seed,
                    compare_static=args.compare_static,
                    queue_depth=args.queue_depth, deadline_ms=dl,
                    deadline_frac=args.deadline_frac,
                    prefix_cache=args.prefix_cache,
                    prefix_len=args.prefix_len, spf=args.spf,
                    replicas=args.replicas, route=args.route,
                    mem_len=args.mem_len, sharding=sharding,
                    prefill_chunk=args.prefill_chunk)
    else:
        serve_loop(model, params, batch=args.batch,
                   prompt_len=args.prompt_len, gen=args.gen,
                   max_len=args.prompt_len + args.gen + 1)


if __name__ == "__main__":
    main()
