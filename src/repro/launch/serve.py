"""Batched serving driver (prefill + decode loop) for dense or pruned models.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b-reduced \
        --batch 4 --prompt-len 32 --gen 16

Reports prefill latency and decode throughput; with --ckpt-in it serves a
pruned checkpoint produced by repro.launch.prune (pass --sparsity to match).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.launch.train import resolve_config
from repro.models import build_model


def serve_loop(model, params, *, batch, prompt_len, gen, max_len,
               seed=0, log=print):
    cfg = model.cfg
    rng = np.random.RandomState(seed)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size,
                                   size=(batch, prompt_len)), jnp.int32)
    req = {"tokens": toks}
    if cfg.family == "encdec":
        req["frames"] = jnp.asarray(
            rng.randn(batch, prompt_len, cfg.d_model).astype(np.float32))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode_step)

    # warm up (compile) outside the timed region
    logits, cache = prefill(params, req)
    tok0 = jnp.zeros((batch, 1), jnp.int32)
    _l, _c = decode(params, tok0, cache)
    jax.block_until_ready(_l)

    t0 = time.time()
    logits, cache = prefill(params, req)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None] \
        .astype(jnp.int32)
    t0 = time.time()
    for _ in range(gen):
        out_tokens.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None] \
            .astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    log(f"[serve] prefill {t_prefill*1e3:.1f} ms "
        f"({batch}x{prompt_len} tokens); decode "
        f"{gen} steps in {t_decode*1e3:.1f} ms -> "
        f"{batch*gen/max(t_decode,1e-9):.1f} tok/s")
    return jnp.concatenate(out_tokens, axis=1), t_prefill, t_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--ckpt-in", default=None)
    args = ap.parse_args()

    cfg = resolve_config(args.arch)
    if args.sparsity > 0:
        cfg = cfg.pruned(args.sparsity, args.sparsity)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_in:
        last = latest_step(args.ckpt_in)
        params, _ = restore_checkpoint(args.ckpt_in, last, params)
        print(f"[serve] loaded {args.ckpt_in} step {last}")
    serve_loop(model, params, batch=args.batch, prompt_len=args.prompt_len,
               gen=args.gen, max_len=args.prompt_len + args.gen + 1)


if __name__ == "__main__":
    main()
