"""One-traversal speculative calibration (docs/pipeline.md).

Covers the tentpole contract end to end:
  * the speculative accumulators reconstruct EXACTLY the pass-2 ridge
    statistics of any keep-set inside the candidates (real + complex
    classes) — parity with a dedicated pass-2 traversal;
  * corp_prune(one_traversal=True) consumes the calibration stream once on
    the hit path and matches the two-pass baseline (functionally — the
    class-1 SVD fold is gauge-unique only up to paired singular-vector
    signs, so attention weights are compared through the model);
  * a forced speculative miss (adversarial bottom-k candidates, margin 0)
    falls back to the targeted mini pass 2 and still matches the oracle;
  * phase-"1+2" checkpoints are rejected by two-pass engines and vice
    versa (fingerprint separation);
  * the async checkpoint cadence: background saves, sync-flush at pass
    end, and an in-flight save surviving a simulated restart.
"""
from __future__ import annotations

import itertools
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import (CalibrationEngine, PruneConfig, corp_prune,
                        corp_prune_streamed, discover_units)
from repro.core import ranking as rank_mod
from repro.core import stats as stats_mod
from repro.core.ranking import candidate_attn, candidate_count, covers, \
    rank_attn
from repro.distrib.fault import CalibrationCheckpointer
from repro.models import build_model

from helpers import batch_for, calib_factory, out_of, tiny_cfg

_ATTN = ("attn", "mla", "cross")

#: class-1 attention fold leaves whose raw values are gauge-dependent
#: (SVD sign pairs / rotary phase splits) — parity for them is asserted on
#: model outputs instead
_GAUGE_LEAVES = ("wq", "wk", "bq", "bk", "w_uq_nope", "w_uk_nope",
                 "q_scale", "k_scale")


def _leafname(kp):
    return str(getattr(kp[-1], "key", getattr(kp[-1], "idx", kp[-1])))


def _assert_params_match(ref, got, cfg_pruned, cfg, rtol=2e-4, atol=2e-5):
    """Non-gauge leaves allclose; attention gauge leaves through outputs."""
    flat_r = jax.tree_util.tree_flatten_with_path(ref)[0]
    flat_g = jax.tree.leaves(got)
    for (kp, a), b in zip(flat_r, flat_g):
        if _leafname(kp) in _GAUGE_LEAVES:
            continue
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=rtol, atol=atol, err_msg=str(kp))
    m = build_model(cfg_pruned)
    y_ref = out_of(m, ref, batch_for(cfg))
    y_got = out_of(m, got, batch_for(cfg))
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_got, np.float32),
                               rtol=1e-4, atol=1e-5)


def _counted(factory):
    calls = [0]

    def make():
        calls[0] += 1
        return factory()
    return make, calls


# ---------------------------------------------------------------------------
# speculative statistics == dedicated pass-2 statistics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["deit-base", "granite-8b", "gemma3-1b"])
def test_spec_reconstruction_matches_pass2(arch):
    """For a keep-set inside the candidates, spec_reconstruct must equal
    the dedicated pass-2 traversal's (G, h, t2) — class 1 (deit), rope
    complex class 2 (granite), and rope+qk-norm class 3 (gemma3)."""
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    units = discover_units(cfg)
    calib = calib_factory(cfg, n=3)
    p1 = CalibrationEngine(model, units, phase=1).run(params, calib())
    attn_units = [u for u in units if u.kind in _ATTN]
    plan, spec_plan = {}, {}
    for u in attn_units:
        full = p1[u.name]["rank"].shape[-1]
        keep_n = max(1, full // 2)
        plan[u.name] = rank_attn(p1[u.name], keep_n)
        # same stats for candidates and final ranking -> keep is inside the
        # candidates by construction (top-n of top-c)
        spec_plan[u.name] = candidate_attn(p1[u.name], keep_n, 0.5)
        assert covers(spec_plan[u.name], plan[u.name][0])
    combined = CalibrationEngine(model, units, phase="1+2",
                                 spec_plan=spec_plan).run(params, calib())
    p2 = CalibrationEngine(model, units, phase=2, plan=plan) \
        .run(params, calib())
    # the fused pass-1 side is the plain pass 1
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
        p1, combined["p1"])
    for u in attn_units:
        rec = stats_mod.spec_reconstruct(combined["p2spec"][u.name],
                                         spec_plan[u.name],
                                         plan[u.name][0], u)
        for k in ("G", "h", "t2"):
            a = np.asarray(p2[u.name][k])
            b = np.asarray(rec[k])
            assert a.shape == b.shape and a.dtype == b.dtype, (k, a.dtype,
                                                              b.dtype)
            scale = max(float(np.max(np.abs(a))), 1e-12)
            np.testing.assert_allclose(b, a, rtol=0, atol=2e-4 * scale,
                                       err_msg=f"{u.name}/{k}")


def test_candidate_count_policy():
    assert candidate_count(16, 8, 0.0) == 8
    assert candidate_count(16, 8, 0.25) == 10
    assert candidate_count(16, 8, 1.0) == 16     # clipped to the unit
    assert candidate_count(16, 8, 10.0) == 16
    with pytest.raises(AssertionError):
        candidate_count(16, 8, -0.1)


# ---------------------------------------------------------------------------
# end-to-end: hit path, miss path, zero-sparsity oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["deit-base", "granite-8b"])
def test_one_traversal_hit_matches_two_pass(arch):
    """Forced-hit margin (candidates = full width): exactly one traversal,
    zero misses, pruned params match the two-pass oracle."""
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    calib = calib_factory(cfg, n=3)
    pc = PruneConfig(0.5, 0.5)
    p_ref, c_ref, _ = corp_prune(model, params, calib, pc)
    counted, calls = _counted(calib)
    p_one, c_one, rep = corp_prune(model, params, counted, pc,
                                   one_traversal=True, spec_margin=1.0)
    assert c_ref == c_one
    assert rep["traversals"] == 1 and calls[0] == 1
    assert rep["speculative"]["misses"] == []
    assert rep["speculative"]["hits"]
    _assert_params_match(p_ref, p_one, c_ref, cfg)


def test_one_traversal_miss_falls_back(monkeypatch):
    """Adversarial candidates (bottom-k scores, margin 0) force a miss:
    the targeted re-pass must reproduce the two-pass oracle, costing
    exactly one extra traversal."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    calib = calib_factory(cfg, n=3)
    pc = PruneConfig(0.5, 0.5)
    p_ref, c_ref, _ = corp_prune(model, params, calib, pc)

    orig = rank_mod.candidate_attn

    def adversarial(stats, keep_n, margin):
        flipped = {"rank": -np.asarray(stats["rank"], np.float64)}
        return orig(flipped, keep_n, 0.0)
    monkeypatch.setattr(rank_mod, "candidate_attn", adversarial)

    counted, calls = _counted(calib)
    p_one, c_one, rep = corp_prune(model, params, counted, pc,
                                   one_traversal=True)
    assert c_ref == c_one
    assert rep["speculative"]["misses"], rep["speculative"]
    assert rep["traversals"] == 2 and calls[0] == 2
    _assert_params_match(p_ref, p_one, c_ref, cfg)


def test_one_traversal_zero_sparsity_bitwise():
    """The zero-sparsity oracle must hold under one_traversal: nothing to
    speculate on (no unit enters the plan), params bitwise identical."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    new_p, new_c, rep = corp_prune(model, params, calib_factory(cfg, n=2),
                                   PruneConfig(0.0, 0.0),
                                   one_traversal=True)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert rep["traversals"] == 1
    assert "speculative" not in rep      # attn_sparsity 0 -> no speculation


def test_one_traversal_streamed_and_bf16():
    """Composition: corp_prune_streamed(one_traversal=True) saves the
    per-group second traversal, and bf16 streaming rides along."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    calib = calib_factory(cfg, n=3)
    pc = PruneConfig(0.5, 0.5)
    counted_ref, calls_ref = _counted(calib)
    p_ref, c_ref, _ = corp_prune_streamed(model, params, counted_ref, pc,
                                          unit_group_size=1)
    counted_one, calls_one = _counted(calib)
    p_one, c_one, rep = corp_prune_streamed(model, params, counted_one, pc,
                                            unit_group_size=1,
                                            one_traversal=True,
                                            spec_margin=1.0)
    assert c_ref == c_one
    assert rep["speculative"]["misses"] == []
    assert rep["traversals"] == calls_one[0] < calls_ref[0]
    _assert_params_match(p_ref, p_one, c_ref, cfg)

    # bf16 composes: same pipeline, looser tolerance (documented bf16 tol)
    p_bf, c_bf, rep_bf = corp_prune(model, params, calib, pc,
                                    one_traversal=True, spec_margin=1.0,
                                    stats_dtype="bfloat16")
    assert c_bf == c_ref and rep_bf["traversals"] == 1
    m = build_model(c_ref)
    y_ref = out_of(m, p_ref, batch_for(cfg))
    y_bf = out_of(m, p_bf, batch_for(cfg))
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_bf, np.float32),
                               rtol=0.05, atol=0.05)


# ---------------------------------------------------------------------------
# fingerprint separation + checkpoint rejection
# ---------------------------------------------------------------------------

def test_spec_fingerprint_separation(tmp_path):
    """Speculative checkpoints must be rejected by two-pass engines and
    vice versa — phases 1, 2 and "1+2" all hash apart, and "1+2" re-hashes
    per candidate set."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    units = discover_units(cfg)
    calib = calib_factory(cfg, n=3)
    p1 = CalibrationEngine(model, units, phase=1).run(params, calib())
    attn = [u for u in units if u.kind in _ATTN][0]
    full = p1[attn.name]["rank"].shape[-1]
    keep_n = max(1, full // 2)
    plan = {attn.name: rank_attn(p1[attn.name], keep_n)}
    cand_a = {attn.name: candidate_attn(p1[attn.name], keep_n, 0.25)}
    cand_b = {attn.name: candidate_attn(p1[attn.name], keep_n, 0.5)}

    e1 = CalibrationEngine(model, units, phase=1)
    e2 = CalibrationEngine(model, units, phase=2, plan=plan)
    e12a = CalibrationEngine(model, units, phase="1+2", spec_plan=cand_a)
    e12b = CalibrationEngine(model, units, phase="1+2", spec_plan=cand_b)
    fps = [e1.fingerprint, e2.fingerprint, e12a.fingerprint,
           e12b.fingerprint]
    assert len(set(fps)) == 4, fps

    # a speculative checkpoint in a reused dir must NOT resume a phase-1
    # pass (fresh start, identical to a clean run) ...
    ckdir = str(tmp_path / "reused")
    e12a.run(params, calib(),
             checkpointer=CalibrationCheckpointer(ckdir, every=1))
    out = e1.run(params, calib(),
                 checkpointer=CalibrationCheckpointer(ckdir, every=1))
    ref = e1.run(params, calib())
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), out, ref)
    # ... and the phase-1 checkpoint that run just wrote must not resume a
    # speculative pass either
    out12 = e12a.run(params, calib(),
                     checkpointer=CalibrationCheckpointer(
                         str(tmp_path / "reused2"), every=1))
    ref12 = e12a.run(params, calib())
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), out12, ref12)


def test_one_traversal_ckpt_resume(tmp_path):
    """ckpt_dir threads through the fused pass (tag pass12): an
    interrupted one-traversal pass resumes into identical pruned params."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(8))
    calib = calib_factory(cfg, n=4)
    pc = PruneConfig(0.5, 0.5)
    ckdir = str(tmp_path / "prune")
    p_a, c_a, _ = corp_prune(model, params, calib, pc, one_traversal=True,
                             spec_margin=1.0, ckpt_dir=ckdir, ckpt_every=1)
    assert (tmp_path / "prune" / "pass12").exists()
    p_b, c_b, _ = corp_prune(model, params, calib, pc, one_traversal=True,
                             spec_margin=1.0, ckpt_dir=ckdir, ckpt_every=1)
    assert c_a == c_b
    _assert_params_match(p_a, p_b, c_a, cfg, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# async checkpoint cadence
# ---------------------------------------------------------------------------

def test_async_checkpoint_resume_matches_uninterrupted(tmp_path):
    """The default (async) cadence must reproduce the sync semantics:
    interrupt after 2 of 4 batches, resume, land on identical sums."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(9))
    calib = calib_factory(cfg, n=4)
    units = discover_units(cfg)
    eng = CalibrationEngine(model, units, phase=1)
    ref = eng.run(params, calib())
    ckdir = str(tmp_path / "calib")
    eng.run(params, itertools.islice(calib(), 2),
            checkpointer=CalibrationCheckpointer(ckdir, every=1))
    resumed = eng.run(params, calib(),
                      checkpointer=CalibrationCheckpointer(ckdir, every=1))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), resumed, ref)


def test_async_save_does_not_block_and_flushes(tmp_path, monkeypatch):
    """maybe_save must return before the write lands (background thread);
    finish() must block until it is durable."""
    from repro.checkpoint import ckpt as ckpt_mod
    from repro.checkpoint import latest_step

    gate = threading.Event()
    real_save = ckpt_mod.save_checkpoint

    def slow_save(*a, **kw):
        gate.wait(timeout=10)
        return real_save(*a, **kw)
    monkeypatch.setattr(ckpt_mod, "save_checkpoint", slow_save)

    ck = CalibrationCheckpointer(str(tmp_path / "c"), every=1)
    acc = {"s": np.arange(4, dtype=np.float32)}
    t0 = time.perf_counter()
    ck.maybe_save(acc, 1, "fp")
    assert time.perf_counter() - t0 < 5.0       # returned while gated
    assert latest_step(str(tmp_path / "c")) is None   # not on disk yet
    gate.set()
    ck.finish()
    assert latest_step(str(tmp_path / "c")) == 1


def test_async_inflight_save_survives_restart(tmp_path, monkeypatch):
    """A restart racing an in-flight save must only ever see complete
    checkpoints: the older valid step while the save is in flight, the new
    step once it lands — never corruption."""
    from repro.checkpoint import ckpt as ckpt_mod

    ckdir = str(tmp_path / "c")
    like = {"s": np.zeros(4, np.float32)}
    # step 1 lands normally
    ck = CalibrationCheckpointer(ckdir, every=1)
    ck.maybe_save({"s": np.full(4, 1.0, np.float32)}, 1, "fp")
    ck.finish()

    # step 2's write is held in flight
    gate = threading.Event()
    real_save = ckpt_mod.save_checkpoint

    def slow_save(*a, **kw):
        gate.wait(timeout=10)
        return real_save(*a, **kw)
    monkeypatch.setattr(ckpt_mod, "save_checkpoint", slow_save)
    ck.maybe_save({"s": np.full(4, 2.0, np.float32)}, 2, "fp")

    # simulated restart: a NEW checkpointer (new process, in spirit) sees
    # the newest COMPLETE checkpoint — step 1
    acc, start = CalibrationCheckpointer(ckdir, every=1).restore(like, "fp")
    assert start == 1 and float(acc["s"][0]) == 1.0

    # the in-flight save completes -> the next restart resumes step 2
    gate.set()
    ck.finish()
    acc, start = CalibrationCheckpointer(ckdir, every=1).restore(like, "fp")
    assert start == 2 and float(acc["s"][0]) == 2.0


def test_sync_mode_still_available(tmp_path):
    """async_save=False preserves the strictly synchronous cadence."""
    ck = CalibrationCheckpointer(str(tmp_path / "c"), every=1,
                                 async_save=False)
    from repro.checkpoint import latest_step
    ck.maybe_save({"s": np.ones(2, np.float32)}, 1, "fp")
    assert latest_step(str(tmp_path / "c")) == 1    # landed synchronously
    ck.finish()                                      # no-op
