"""Mesh-sharded ServeEngine: deviceless placement rules + live-mesh parity.

Two layers (docs/serving.md "Mesh-sharded serving"):

  * deviceless — ``slot_specs`` / ``device_bytes_estimate`` driven with
    plain ``{axis: size}`` dict meshes over the engines' own structurally
    inferred cache templates (``SlotCache._template`` / ``batch_axes``),
    so the per-leaf placement rules, the batch-1 data-replication rule,
    the all-or-nothing refusal, and the footprint arithmetic all run in
    the plain single-device suite (and the serve-coverage job);
  * subprocess — a forced 4-device (2 data x 2 model) host mesh where the
    sharded engine must stream token-identical to the single-device
    engine for one kv, one recurrent, and one MoE/MLA config on a ragged
    trace (slots refill mid-flight), every live cache leaf's sharding
    equals its ``slot_specs`` spec, measured per-device bytes equal the
    analytic estimate, and the ``--serve-sharded`` CLI path works end to
    end. Subprocess tests carry the registered ``subprocess`` marker so
    ``-m "not subprocess"`` deselects them on minimal hosts.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

import jax
import pytest

from helpers import tiny_cfg
from repro.models import build_model
from repro.serve import (ServeEngine, cache_contract, device_bytes_estimate,
                         slot_specs)
from repro.serve import errors
from repro.serve.sharding import MODEL_DIM_FROM_END, REPLICATED_SLOT_LEAVES

ROOT = os.path.join(os.path.dirname(__file__), "..")
MESH2 = {"data": 2, "model": 2}

# one arch per slot-cache contract family (all shard-eligible reduced)
ELIGIBLE = {"deepseek-7b": "kv", "rwkv6-3b": "recurrent",
            "seamless-m4t-large-v2": "encdec", "deepseek-v3-671b": "kv"}


def run_py(code: str, devices: int = 4, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep \
        + os.path.dirname(__file__)
    # force CPU: without this, jax probes the TPU backend and each
    # subprocess stalls minutes in libtpu metadata retries (see
    # test_sharded_calibration.run_py)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def _engine(arch, n_slots=2, max_len=32):
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = {"mem_len": 8} if cache_contract(cfg) == "encdec" else {}
    return cfg, ServeEngine(model, params, n_slots=n_slots,
                            max_len=max_len, **kw)


def _leaf_items(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for kp, leaf in flat:
        yield str(getattr(kp[-1], "key", kp[-1])), leaf


# ---------------------------------------------------------------------------
# deviceless: placement rules over real engine templates (dict meshes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(ELIGIBLE))
def test_slot_specs_match_contract_per_leaf(arch):
    """Every leaf of a real engine's cache template lands where its
    contract family says: payload leaves model-sharded on the
    MODEL_DIM_FROM_END dim, bookkeeping leaves model-replicated, and the
    inferred slot axis data-sharded (n_slots=2 divides data=2)."""
    cfg, eng = _engine(arch)
    assert cache_contract(cfg) == ELIGIBLE[arch]
    sc = eng.slotcache
    sp = slot_specs(sc._template, sc.batch_axes, MESH2, name=cfg.name)
    payload = 0
    for (name, leaf), spec, slot_ax in zip(
            _leaf_items(sc._template),
            jax.tree_util.tree_leaves(
                sp, is_leaf=lambda s: isinstance(s, tuple)),
            jax.tree_util.tree_leaves(sc.batch_axes)):
        spec = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
        assert spec[slot_ax] == "data", (name, spec)
        if name in REPLICATED_SLOT_LEAVES:
            assert "model" not in spec, (name, spec)
        elif name in MODEL_DIM_FROM_END:
            md = leaf.ndim - MODEL_DIM_FROM_END[name]
            assert spec[md] == "model", (name, spec, md)
            assert leaf.shape[md] % MESH2["model"] == 0
            payload += 1
        else:
            assert "model" not in spec, (name, spec)
    assert payload, f"{arch}: no model-sharded payload leaf"


def test_local_batch1_template_is_data_replicated():
    """The batch-1 prefill template (what scatter-admit places) keeps the
    model split but never the data split — exactly the rule the engine's
    pinned out_shardings rely on."""
    cfg, eng = _engine("deepseek-7b", n_slots=1)
    one = eng.slotcache
    local = slot_specs(one._template, one.batch_axes, MESH2, name=cfg.name)
    flat = [tuple(s) for s in jax.tree_util.tree_leaves(
        local, is_leaf=lambda s: isinstance(s, tuple))]
    assert all("data" not in s for s in flat), flat
    assert any("model" in s for s in flat), flat


def test_model_only_mesh_never_touches_slot_axis():
    cfg, eng = _engine("rwkv6-3b")
    sc = eng.slotcache
    sp = slot_specs(sc._template, sc.batch_axes, {"model": 2},
                    name=cfg.name)
    flat = [tuple(s) for s in jax.tree_util.tree_leaves(
        sp, is_leaf=lambda s: isinstance(s, tuple))]
    assert all("data" not in s for s in flat), flat
    assert any("model" in s for s in flat), flat


def test_ineligible_config_refused_never_padded():
    """A reduced GQA config collapsing to one kv head must refuse with the
    single-sourced shard_ineligible message — all-or-nothing, no padding
    (the zoo matrix in test_serve_zoo.py pins the full arch list)."""
    cfg, eng = _engine("granite-8b")
    sc = eng.slotcache
    expect = errors.msg("shard_ineligible", name=cfg.name, leaf="k", m=2)
    with pytest.raises(ValueError, match=re.escape(expect)):
        slot_specs(sc._template, sc.batch_axes, MESH2, name=cfg.name)


def test_device_bytes_estimate_splits_payload_only():
    """Estimate == payload/(d*m) + replicated bookkeeping/d: the only slack
    against a perfect 1/N split is the replicated pos-style leaves."""
    cfg, eng = _engine("deepseek-7b")
    sc = eng.slotcache
    sp = slot_specs(sc._template, sc.batch_axes, MESH2, name=cfg.name)
    est = device_bytes_estimate(sc._template, sp, MESH2)
    total = eng.cache_bytes
    repl = sum(leaf.size * leaf.dtype.itemsize
               for name, leaf in _leaf_items(sc._template)
               if name in REPLICATED_SLOT_LEAVES)
    n_dev = MESH2["data"] * MESH2["model"]
    # payload splits n_dev ways; replicated leaves split only over data
    assert est == (total - repl) // n_dev + repl // MESH2["data"], \
        (est, total, repl)
    assert est < total


def test_degenerate_mesh_is_identity():
    """A (1, 1) mesh runs the entire sharded code path — param placement,
    spec'd cache allocation, pinned out_shardings on decode/prefill/write
    — on the suite's single device, and must stream exactly like the
    unsharded engine (the live multi-device version of this parity is
    the subprocess test below and benchmarks/bench_serve_sharded.py)."""
    from repro.launch.mesh import make_mesh
    from repro.serve import ServeSharding, synthetic_trace
    cfg, ref_eng = _engine("deepseek-7b")
    sharding = ServeSharding(make_mesh((1, 1)))
    assert sharding.sizes == {"data": 1, "model": 1}
    assert sharding.data_size == 1 and sharding.model_size == 1
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shard_eng = ServeEngine(model, params, n_slots=2, max_len=32,
                            sharding=sharding)
    trace = synthetic_trace(4, cfg.vocab_size, seed=5,
                            prompt_range=(4, 8), gen_range=(2, 5))
    for a, b in zip(ref_eng.run(trace), shard_eng.run(trace)):
        assert list(a.tokens) == list(b.tokens), a.rid
    # one device: per-device bytes are total bytes, sharded or not
    assert shard_eng.device_cache_bytes == shard_eng.cache_bytes
    assert ref_eng.device_cache_bytes == ref_eng.cache_bytes
    est = device_bytes_estimate(shard_eng.slotcache._template,
                                shard_eng.slotcache.specs, sharding.sizes)
    assert est == shard_eng.device_cache_bytes


# ---------------------------------------------------------------------------
# live 4-device mesh (subprocess: device count must precede jax init)
# ---------------------------------------------------------------------------

@pytest.mark.subprocess
def test_sharded_engine_token_parity_and_leaf_placement():
    """One kv, one recurrent, one MoE/MLA config on a (2 data x 2 model)
    mesh: sharded streams token-identical to single-device (6 requests
    through 2 slots, so retire/refill happens mid-flight on sharded
    state), every live global-cache leaf carries exactly its slot_specs
    placement, and measured per-device bytes == the analytic estimate."""
    out = run_py("""
import dataclasses
import jax, numpy as np
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.serve import (ServeEngine, ServeSharding, device_bytes_estimate,
                         slot_specs, synthetic_trace)
from helpers import tiny_cfg

assert len(jax.devices()) == 4
mesh = make_mesh((2, 2))
sharding = ServeSharding(mesh)

def zoo(arch):
    cfg = tiny_cfg(arch)
    if cfg.moe is not None:   # capacity bump: greedy parity must be exact
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg

for arch in ("deepseek-7b", "rwkv6-3b", "deepseek-v3-671b"):
    cfg = zoo(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = synthetic_trace(6, cfg.vocab_size, seed=3,
                            prompt_range=(4, 10), gen_range=(2, 6))
    single = ServeEngine(model, params, n_slots=2, max_len=32)
    shard = ServeEngine(model, params, n_slots=2, max_len=32,
                        sharding=sharding)
    ref = single.run(trace)
    got = shard.run(trace)
    assert single.stats["refills"] > 0
    for a, b in zip(ref, got):
        assert list(a.tokens) == list(b.tokens), (arch, a.rid)

    # live placement == slot_specs, per leaf
    sc = shard.slotcache
    specs = jax.tree_util.tree_leaves(
        sc.specs, is_leaf=lambda s: isinstance(s, tuple))
    for leaf, spec in zip(jax.tree_util.tree_leaves(sc.cache), specs):
        assert tuple(leaf.sharding.spec) == tuple(spec), \\
            (arch, leaf.shape, leaf.sharding.spec, spec)
    est = device_bytes_estimate(sc._template, sc.specs, sharding.sizes)
    assert shard.device_cache_bytes == est, \\
        (arch, shard.device_cache_bytes, est)
    assert single.cache_bytes / shard.device_cache_bytes >= 3.6
    print(arch, "OK")
print("OK")
""")
    assert out.count("OK") == 4


@pytest.mark.subprocess
def test_sharded_retire_resets_shard_local_state():
    """Retire/cancel must zero exactly the retired slot's shards: after a
    mixed admit/cancel/retire sequence the sharded cache equals a fresh
    cache wherever slots are free, and a still-running slot's payload is
    untouched by its neighbour's retirement."""
    out = run_py("""
import jax, numpy as np
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.serve import ServeEngine, ServeSharding
from repro.serve.engine import Request
from helpers import tiny_cfg

mesh = make_mesh((2, 2))
cfg = tiny_cfg("rwkv6-3b")   # recurrent: reset-on-retire is load-bearing
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
eng = ServeEngine(model, params, n_slots=2, max_len=32,
                  sharding=ServeSharding(mesh))
eng.begin()
r0 = Request(rid=0, tokens=np.arange(1, 5, dtype=np.int32), gen=8)
r1 = Request(rid=1, tokens=np.arange(2, 8, dtype=np.int32), gen=8)
eng.admit(r0, 0)
eng.admit(r1, 1)
eng.decode_step()
before = jax.tree.map(lambda x: np.asarray(x), eng.slotcache.cache)
eng.cancel(0)                       # shard-local zero-reset of slot 0
after = jax.tree.map(lambda x: np.asarray(x), eng.slotcache.cache)
axes = eng.slotcache.batch_axes
changed = kept = 0
for b, a, ax in zip(jax.tree.leaves(before), jax.tree.leaves(after),
                    jax.tree.leaves(axes)):
    b, a = np.moveaxis(b, ax, 0), np.moveaxis(a, ax, 0)
    np.testing.assert_array_equal(a[1], b[1])     # slot 1 untouched
    assert not a[0].any()                         # slot 0 zeroed
    if b[0].any():
        changed += 1
    kept += 1
assert changed > 0 and kept > 0
eng.decode_step()                   # survivor still decodes fine
print("OK")
""")
    assert "OK" in out


@pytest.mark.subprocess
def test_serve_cli_sharded_end_to_end():
    """--serve-sharded --mesh-shape 2x2 forces the host devices, builds
    the mesh, and reports the per-device cache line; --serve-sharded
    without --mesh-shape is a usage error."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "deepseek-7b-reduced", "--trace", "6", "--slots", "2",
         "--max-len", "48",
         "--prompt-range", "4,10", "--gen-range", "2,6",
         "--serve-sharded", "--mesh-shape", "2x2"],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "sharded over {'data': 2, 'model': 2}" in r.stdout, r.stdout
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "deepseek-7b-reduced", "--trace", "4", "--serve-sharded"],
        env=env, capture_output=True, text=True, timeout=120)
    assert r2.returncode != 0
    assert "--serve-sharded requires --mesh-shape" in r2.stderr
