"""CORP attention compensation: closed-form identities (App. B.2/C.2).

  * the Kronecker ridge solution matches a direct vectorized lstsq over the
    calibration samples (Eq. 15)
  * the SVD fold reproduces I + M exactly (Eq. 16)
  * J* = sum ||T_b||^2 - h^T G^+ h matches the empirical logit residual
    (Prop C.2.1) and the gain is non-negative (Prop C.2.2)
  * rope-aware classes: the diagonal complex/real compensators commute with
    rotary phases — folded pre-rope weights reproduce the post-rope
    compensated logits exactly
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_shim import given, settings, st

from repro.core import solve as S


def qk_samples(rng, n, t, d, corr=True):
    qs, ks = [], []
    for _ in range(n):
        q = rng.randn(t, d).astype(np.float32)
        k = rng.randn(t, d).astype(np.float32)
        if corr:
            mix = np.eye(d) + 0.5 * rng.randn(d, d) / np.sqrt(d)
            q = q @ mix.astype(np.float32)
            k = k @ mix.astype(np.float32).T
        qs.append(q)
        ks.append(k)
    return qs, ks


def build_G_h(qs, ks, keep_n):
    """Accumulate paper Eq. 15 inputs (row-major vec convention)."""
    d = qs[0].shape[1]
    ds = keep_n
    G = np.zeros((ds * ds, ds * ds))
    h = np.zeros(ds * ds)
    t2 = 0.0
    for q, k in zip(qs, ks):
        qS, qP = q[:, :ds], q[:, ds:]
        kS, kP = k[:, :ds], k[:, ds:]
        A = qS.T @ qS
        C = kS.T @ kS
        G += np.einsum("ij,lk->iljk", A, C).reshape(ds * ds, ds * ds)
        h += (qS.T @ qP @ kP.T @ kS).reshape(-1)
        t2 += np.sum((qP @ kP.T) ** 2)
    return G, h, t2


def test_kron_solution_matches_direct_lstsq():
    rng = np.random.RandomState(0)
    d, ds, t, n = 8, 5, 32, 12
    qs, ks = qk_samples(rng, n, t, d)
    G, h, t2 = build_G_h(qs, ks, ds)
    sol = S.solve_full_m(jnp.asarray(G, jnp.float32),
                         jnp.asarray(h, jnp.float32), t2, lam=1e-8)
    # direct: stack rows of the linear system T_b ~ Q_S M K_S^T over b
    rows, tgt = [], []
    for q, k in zip(qs, ks):
        qS, kS = q[:, :ds], k[:, :ds]
        T = q[:, ds:] @ k[:, ds:].T
        # vec_row(Q M K^T) = (Q kron K) vec_row(M)
        rows.append(np.kron(qS, kS))
        tgt.append(T.reshape(-1))
    A = np.concatenate(rows)
    y = np.concatenate(tgt)
    m_direct, *_ = np.linalg.lstsq(A, y, rcond=None)
    np.testing.assert_allclose(np.asarray(sol["M"]).reshape(-1), m_direct,
                               rtol=5e-2, atol=5e-3)


def test_svd_fold_reproduces_I_plus_M():
    rng = np.random.RandomState(1)
    ds = 6
    M = jnp.asarray(rng.randn(ds, ds).astype(np.float32) * 0.3)
    fq, fk = S.fold_full_m(M)
    np.testing.assert_allclose(np.asarray(fq @ fk.T),
                               np.eye(ds) + np.asarray(M), rtol=1e-4,
                               atol=1e-5)


def test_attention_distortion_matches_empirical():
    rng = np.random.RandomState(2)
    d, ds, t, n = 10, 6, 24, 16
    qs, ks = qk_samples(rng, n, t, d)
    G, h, t2 = build_G_h(qs, ks, ds)
    sol = S.solve_full_m(jnp.asarray(G, jnp.float32),
                         jnp.asarray(h, jnp.float32), t2, lam=1e-8)
    M = np.asarray(sol["M"])
    emp = 0.0
    for q, k in zip(qs, ks):
        T = q[:, ds:] @ k[:, ds:].T
        emp += np.sum((T - q[:, :ds] @ M @ k[:, :ds].T) ** 2)
    assert float(sol["j_star"]) == pytest.approx(emp, rel=2e-2)
    assert 0.0 <= float(sol["rho2"]) <= 1.0
    assert float(sol["j_star"]) <= t2 * (1 + 1e-6)     # gain >= 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 9999), ds=st.integers(2, 6))
def test_attention_gain_nonnegative_property(seed, ds):
    rng = np.random.RandomState(seed)
    d = ds + rng.randint(1, 5)
    qs, ks = qk_samples(rng, 6, 16, d, corr=bool(seed % 2))
    G, h, t2 = build_G_h(qs, ks, ds)
    sol = S.solve_full_m(jnp.asarray(G, jnp.float32),
                         jnp.asarray(h, jnp.float32), t2, lam=1e-6)
    assert float(sol["j_star"]) <= t2 * (1 + 1e-5)


# ---------------------------------------------------------------------------
# rope-aware classes (beyond-paper, DESIGN.md §2.2)
# ---------------------------------------------------------------------------

def rope_rotate(x, pos, theta=100.0):
    d = x.shape[-1]
    inv = 1.0 / theta ** (np.arange(0, d, 2) / d)
    ang = pos[:, None] * inv[None, :]
    c, s = np.cos(ang), np.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = np.empty_like(x)
    out[..., 0::2] = x1 * c - x2 * s
    out[..., 1::2] = x2 * c + x1 * s
    return out


def test_diag_complex_fold_commutes_with_rope():
    """Folding the per-pair 2x2 blocks pre-rope reproduces the compensated
    post-rope logits: rope(q F_q) rope(k F_k)^T == Re(qc (1+m) conj(kc))
    with phases — verified numerically end-to-end."""
    rng = np.random.RandomState(3)
    t, dp = 12, 4                  # dp kept pairs
    d = 2 * dp
    q = rng.randn(t, d).astype(np.float32)
    k = rng.randn(t, d).astype(np.float32)
    pos = np.arange(t).astype(np.float32)
    m = (rng.randn(dp) * 0.3 + 1j * rng.randn(dp) * 0.3).astype(np.complex64)
    fq, fk = S.fold_diag_complex(jnp.asarray(m))
    fq, fk = np.asarray(fq), np.asarray(fk)

    def apply_blocks(x, blocks):
        xp = x.reshape(t, dp, 2)
        return np.einsum("tpi,pij->tpj", xp, blocks).reshape(t, d)

    # folded path: fold pre-rope, then rotate, then plain dot
    lq = rope_rotate(apply_blocks(q, fq), pos)
    lk = rope_rotate(apply_blocks(k, fk), pos)
    logits_fold = lq @ lk.T

    # reference path: rotate first, then apply diag(1+m) in complex space
    qc = rope_rotate(q, pos)
    kc = rope_rotate(k, pos)
    qz = qc[:, 0::2] + 1j * qc[:, 1::2]
    kz = kc[:, 0::2] + 1j * kc[:, 1::2]
    logits_ref = np.real(qz @ np.diag(1 + m) @ np.conj(kz).T)
    np.testing.assert_allclose(logits_fold, logits_ref, rtol=1e-3,
                               atol=1e-3)


def test_diag_complex_solver_reduces_residual():
    rng = np.random.RandomState(4)
    t, dp_keep, dp_full, n = 24, 4, 7, 10
    Gd = np.zeros((dp_keep, dp_keep), np.complex64)
    hd = np.zeros(dp_keep, np.complex64)
    t2 = 0.0
    samples = []
    for _ in range(n):
        qz = (rng.randn(t, dp_full) + 1j * rng.randn(t, dp_full)) \
            .astype(np.complex64)
        kz = (qz * 0.5 + 0.5 * (rng.randn(t, dp_full)
                                + 1j * rng.randn(t, dp_full))) \
            .astype(np.complex64)
        qS, qP = qz[:, :dp_keep], qz[:, dp_keep:]
        kS, kP = kz[:, :dp_keep], kz[:, dp_keep:]
        A = np.conj(qS).T @ qS
        C = np.conj(kS).T @ kS
        Gd += A * C.T
        hd += np.diag(np.conj(qS).T @ qP @ np.conj(kP).T @ kS)
        t2 += np.sum(np.abs(qP @ np.conj(kP).T) ** 2)
        samples.append((qS, qP, kS, kP))
    sol = S.solve_diag_complex(jnp.asarray(Gd), jnp.asarray(hd), t2, 1e-6)
    m = np.asarray(sol["m"])
    emp = sum(np.sum(np.abs(qP @ np.conj(kP).T
                            - qS @ np.diag(m) @ np.conj(kS).T) ** 2)
              for qS, qP, kS, kP in samples)
    assert float(sol["j_star"]) == pytest.approx(float(emp), rel=3e-2)
    assert float(sol["j_star"]) <= t2   # compensation helps


def test_diag_real_fold_sign_and_scale():
    m = jnp.asarray([0.5, -2.5, 0.0])
    sq, sk = S.fold_diag_real(m)
    np.testing.assert_allclose(np.asarray(sq) * np.asarray(sk),
                               np.asarray(1 + m), rtol=1e-6)
