"""Mesh-sharded CalibrationEngine (subprocess: the forced host device count
must be set before jax initializes, as in test_sharding.py).

The sharding contract under test (docs/calibration.md):
  * statistics parity — the sharded engine is a partitioning of the
    single-device engine (linear reductions), allclose in fp32;
  * no replicated Sigma — every dense unit's second moment is column-sharded
    over the model axis (asserted via the accumulator's sharding specs and
    the addressable shard shapes);
  * sharded checkpoint round-trip — gathered-on-save, re-placed per
    ``stat_shardings`` on restore, landing on the uninterrupted sums;
  * foreign-mesh rejection — a checkpoint written under a different mesh
    layout has a different fingerprint and is ignored (fresh start).
"""
from __future__ import annotations

import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_py(code: str, devices: int = 4, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep \
        + os.path.dirname(__file__)
    # force CPU: with JAX_PLATFORMS unset, jax probes the TPU backend and
    # on TPU-shaped containers without TPU metadata each subprocess stalls
    # ~7 minutes in libtpu GCP-metadata retries before falling back
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_engine_stat_parity_and_specs():
    """Sharded stats == single-device stats (both passes, fp32 allclose) on
    a forced 4-device (2 data x 2 model) mesh, with every dense unit's s2
    column-sharded — the addressable shard holds F/m columns, never F."""
    out = run_py("""
import jax, numpy as np
from repro.core import CalibrationEngine, discover_units
from repro.core.ranking import rank_attn
from repro.models import build_model
from repro.launch.mesh import make_mesh
from helpers import tiny_cfg, calib_factory

assert len(jax.devices()) == 4
def close(a, b, tol=2e-4):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=tol, atol=tol), a, b)

mesh = make_mesh((2, 2))
for arch in ("deit-base", "granite-8b"):
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    units = discover_units(cfg)
    calib = calib_factory(cfg, n=3)
    ref = CalibrationEngine(model, units, phase=1).run(params, calib())
    eng = CalibrationEngine(model, units, phase=1, mesh=mesh)
    sh = eng.run(params, calib())
    close(sh, ref)

    # no replicated Sigma: dense-unit second moments are model-sharded
    acc = eng.init_stats(params, next(iter(calib())))
    checked = 0
    for u in units:
        if u.kind not in ("mlp", "rwkv_mlp", "mamba"):
            continue
        a = acc[u.name]["s2"]
        spec = tuple(a.sharding.spec)
        assert spec[-1] == "model" and spec[:-1] == (None,) * (a.ndim - 1), \\
            (u.name, spec)
        local = a.addressable_shards[0].data.shape
        assert local[-1] == a.shape[-1] // 2, (u.name, a.shape, local)
        checked += 1
    assert checked, arch

    # pass 2 parity (ridge-system inputs; complex classes on granite)
    plan = {}
    for u in units:
        if u.kind in ("attn", "mla", "cross"):
            full = ref[u.name]["rank"].shape[-1]
            plan[u.name] = rank_attn(ref[u.name], max(1, full // 2))
    p2_ref = CalibrationEngine(model, units, phase=2, plan=plan) \\
        .run(params, calib())
    p2_sh = CalibrationEngine(model, units, phase=2, plan=plan,
                              mesh=mesh).run(params, calib())
    close(p2_sh, p2_ref)
    print(arch, "OK")
print("OK")
""")
    assert "OK" in out


def test_sharded_checkpoint_roundtrip_and_foreign_mesh():
    """A sharded pass killed mid-stream resumes from its checkpoint onto
    identical sums; the same directory offered to an engine on a different
    mesh is rejected by fingerprint (fresh start, still correct)."""
    out = run_py("""
import itertools, tempfile
import jax, numpy as np
from repro.core import CalibrationEngine, discover_units
from repro.distrib.fault import CalibrationCheckpointer
from repro.models import build_model
from repro.launch.mesh import make_mesh
from helpers import tiny_cfg, calib_factory

def close(a, b, tol=1e-6):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=tol, atol=tol), a, b)

cfg = tiny_cfg("deit-base")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(5))
units = discover_units(cfg)
calib = calib_factory(cfg, n=4)
mesh_a = make_mesh((2, 2))
mesh_b = make_mesh((1, 4))
eng_a = CalibrationEngine(model, units, phase=1, mesh=mesh_a)
ref = eng_a.run(params, calib())

with tempfile.TemporaryDirectory() as td:
    # die after 2 of 4 batches, checkpointing every batch
    eng_a.run(params, itertools.islice(calib(), 2),
              checkpointer=CalibrationCheckpointer(td, every=1))
    resumed = eng_a.run(params, calib(),
                        checkpointer=CalibrationCheckpointer(td, every=1))
    close(resumed, ref)
    # the resumed accumulator was re-placed sharded before the donated step
    acc = eng_a.init_stats(params, next(iter(calib())))
    acc2, start = CalibrationCheckpointer(td, every=1).restore(
        acc, eng_a.fingerprint, shardings=eng_a.stat_shardings)
    assert start == 4, start
    for u in units:
        if u.kind == "mlp":
            s2 = acc2[u.name]["s2"]
            assert s2.addressable_shards[0].data.shape[-1] \\
                == s2.shape[-1] // 2, s2.sharding
    print("resume OK")

    # foreign mesh: (1,4) layout must not resume a (2,2) checkpoint
    eng_b = CalibrationEngine(model, units, phase=1, mesh=mesh_b)
    assert eng_a.fingerprint != eng_b.fingerprint
    out_b = eng_b.run(params, calib(),
                      checkpointer=CalibrationCheckpointer(td, every=1))
    close(out_b, eng_b.run(params, calib()))
    # and the unsharded engine is a third, distinct identity
    eng_c = CalibrationEngine(model, units, phase=1)
    assert eng_c.fingerprint not in (eng_a.fingerprint, eng_b.fingerprint)
print("OK")
""")
    assert "OK" in out


def test_sharded_corp_prune_functional_parity():
    """End-to-end: corp_prune(mesh=...) and corp_prune_streamed(mesh=...)
    produce models functionally identical to the single-device pipeline
    (weights can differ by the SVD fold's orthogonal ambiguity, outputs
    cannot)."""
    out = run_py("""
import jax, numpy as np
from repro.core import PruneConfig, corp_prune, corp_prune_streamed
from repro.models import build_model
from repro.launch.mesh import make_mesh
from helpers import tiny_cfg, calib_factory, batch_for, out_of

mesh = make_mesh((2, 2))
cfg = tiny_cfg("deit-base")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(2))
calib = calib_factory(cfg, n=4)
pc = PruneConfig(0.5, 0.5)
p_ref, c_ref, _ = corp_prune(model, params, calib, pc)
p_sh, c_sh, _ = corp_prune(model, params, calib, pc, mesh=mesh)
assert c_ref == c_sh
b = batch_for(cfg)
y_ref = np.asarray(out_of(build_model(c_ref), p_ref, b))
y_sh = np.asarray(out_of(build_model(c_sh), p_sh, b))
np.testing.assert_allclose(y_ref, y_sh, rtol=2e-3, atol=2e-3)
p_st, c_st, rep = corp_prune_streamed(model, params, calib, pc, mesh=mesh)
y_st = np.asarray(out_of(build_model(c_st), p_st, b))
np.testing.assert_allclose(y_ref, y_st, rtol=2e-3, atol=2e-3)
print("OK")
""")
    assert "OK" in out


def test_sharded_one_traversal_functional_parity():
    """Composition (docs/pipeline.md): the phase-"1+2" speculative engine
    under a mesh — candidate accumulators take stats_specs shardings, the
    hit path consumes the stream once, and corp_prune(mesh=...,
    one_traversal=True) matches the single-device two-pass pipeline
    functionally."""
    out = run_py("""
import jax, numpy as np
from repro.core import PruneConfig, corp_prune
from repro.models import build_model
from repro.launch.mesh import make_mesh
from helpers import tiny_cfg, calib_factory, batch_for, out_of

mesh = make_mesh((2, 2))
cfg = tiny_cfg("deit-base")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(11))
calib = calib_factory(cfg, n=4)
pc = PruneConfig(0.5, 0.5)
p_ref, c_ref, _ = corp_prune(model, params, calib, pc)
p_one, c_one, rep = corp_prune(model, params, calib, pc, mesh=mesh,
                               one_traversal=True, spec_margin=1.0)
assert c_ref == c_one
assert rep["traversals"] == 1, rep["traversals"]
assert rep["speculative"]["misses"] == []
b = batch_for(cfg)
y_ref = np.asarray(out_of(build_model(c_ref), p_ref, b))
y_one = np.asarray(out_of(build_model(c_one), p_one, b))
np.testing.assert_allclose(y_ref, y_one, rtol=2e-3, atol=2e-3)
print("OK")
""")
    assert "OK" in out
