"""Property-based slot-lifecycle tests for the serving front-end.

The front-end's scheduling core (`ServeFrontend.step` + the
``serve/scheduler.py`` policy layer) is engine-agnostic: it only touches
the engine's slot surface (``free_slots`` / ``admit`` — or its
``begin_admit``/``continue_admit`` non-atomic split — / ``decode_step`` /
``retire`` / ``cancel`` / ``slots``). That lets this suite drive the
*exact production scheduling code* with a pure-Python ``FakeEngine`` (no
jax, instant "decode") and a manual clock, against an independently
written slot-state oracle, over >= 50 random action sequences per
property (deterministic under the hypothesis shim — see
``tests/hypothesis_shim.py``). The main lifecycle properties run both
with atomic admits and with the scheduler's chunked-prefill policy
(``prefill_chunk``), whose non-atomic PREFILLING state the oracle models
independently: no decode lane until the prompt is consumed, zero tokens
kept on mid-prefill expiry/cancel, and no early cache writes.

Invariants checked on every sequence:
  * every submitted request reaches **exactly one** terminal state
    (DONE / REJECTED / EXPIRED / CANCELLED) — no lost or double retires;
  * **no cross-request contamination**: request ``rid``'s tokens are
    exactly the prefix of the sequence ``FakeEngine`` generates for
    ``rid``, never another request's;
  * DONE handles carry exactly ``gen`` tokens; EXPIRED/CANCELLED carry a
    strict-prefix count; REJECTED carry none plus a typed ``Overloaded``;
  * **no slot leak**: after draining, every engine slot is free and the
    queue is empty;
  * the front-end's admission order and per-request outcomes match the
    oracle exactly (FIFO and shortest-prompt-first policies both).

The mesh-sharded fakes (``ShardedFakeEngine`` / ``ShardedRecurrentFake-
Engine``) additionally model the slot cache as explicit *per-device*
shards over a dict mesh (the deviceless ``{"data": d, "model": m}``
idiom of ``repro.serve.sharding.slot_specs``), and after EVERY action
compare the whole device dict against the oracle's own projection:
per-device leaf shapes never drift, a slot's cells appear only on the
shards that own it (no cross-shard contamination — cell values are
injective in (rid, position, model-shard)), retire/cancel resets are
shard-local, replicated ``pos`` bookkeeping agrees on every device, and
free-slot capacity matches the oracle.
"""
from __future__ import annotations

import random

import numpy as np
import pytest

from hypothesis_shim import given, settings, st
from repro.serve.engine import Request
from repro.serve.frontend import ServeFrontend
from repro.serve.queue import Overloaded, Status, TERMINAL


def fake_token(rid: int, i: int) -> int:
    """The i-th token FakeEngine generates for request ``rid``. Injective
    in (rid, i) so any cross-slot contamination is detectable."""
    return rid * 1000 + i


class _FakeSlot:
    def __init__(self):
        self.rid, self.remaining, self.out, self.req = -1, 0, [], None
        self.pending = None                # prompt tokens left to prefill

    @property
    def free(self):
        return self.req is None


class _Completion:
    def __init__(self, rid, tokens):
        self.rid, self.tokens = rid, tokens


class FakeEngine:
    """Pure-Python stand-in exposing exactly the slot surface the
    front-end uses. One decode_step == one token per active slot."""

    class cfg:
        name, family = "fake", "lm"

    contract = "kv"

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.slots = [_FakeSlot() for _ in range(n_slots)]
        self.admits = 0

    def begin(self, t0=None):
        self._t0 = t0

    def prefix_eligible(self):
        return False

    def free_slots(self):
        return [i for i, s in enumerate(self.slots) if s.free]

    def active_count(self):
        return sum(not s.free for s in self.slots)

    def decoding_count(self):
        return sum((not s.free) and s.pending is None for s in self.slots)

    def begin_admit(self, req, slot, prefix_cache=None):
        """Bind only: the slot is PREFILLING (occupied, zero tokens,
        skipped by decode) until ``continue_admit`` drains the prompt."""
        s = self.slots[slot]
        assert s.free, f"admit into occupied slot {slot}"
        self.admits += 1
        s.rid, s.req = req.rid, req
        s.out = []
        s.remaining = req.gen
        s.pending = len(req.tokens)

    def continue_admit(self, slot, budget=None):
        s = self.slots[slot]
        assert s.pending is not None, f"continue without begin on {slot}"
        take = s.pending if budget is None \
            else min(max(1, int(budget)), s.pending)
        s.pending -= take
        if s.pending:
            return False
        self._install(slot)
        return True

    def _install(self, slot):
        """Prompt consumed: the first token lands. Sharded/recurrent
        subclasses scatter their cache state here — never earlier, the
        real engine holds chunk work aside until this point."""
        s = self.slots[slot]
        s.out = [fake_token(s.rid, 0)]            # "prefill" token
        s.remaining = s.req.gen - 1
        s.pending = None

    def admit(self, req, slot, prefix_cache=None):
        self.begin_admit(req, slot, prefix_cache=prefix_cache)
        self.continue_admit(slot)

    def decode_step(self):
        retired = []
        for i, s in enumerate(self.slots):
            if s.free or s.pending is not None or s.remaining == 0:
                continue
            s.out.append(fake_token(s.rid, len(s.out)))
            s.remaining -= 1
            if s.remaining == 0:
                retired.append(i)
        return retired

    def retire(self, slot):
        s = self.slots[slot]
        assert not s.free, f"retire of free slot {slot}"
        comp = _Completion(s.rid, list(s.out))
        s.rid, s.req, s.remaining, s.pending = -1, None, 0, None
        return comp

    def cancel(self, slot):
        s = self.slots[slot]
        if s.free:
            raise ValueError(f"cancel of free slot {slot}")
        partial = list(s.out)
        s.rid, s.req, s.remaining, s.pending = -1, None, 0, None
        return partial


FAKE_STATE_SIZE = 4                # fixed per-slot state width (recurrent)


class RecurrentFakeEngine(FakeEngine):
    """``FakeEngine`` under the *recurrent* slot-cache contract (docs/
    serving.md "Slot-cache contracts"): per-slot state is a fixed-size
    vector written wholesale at admit (the state scatter), advanced by
    ONE shared recurrent step per ``decode_step``, zeroed at retire and
    cancel, and never grown. The state encodes ``(rid + 1, tokens
    processed)`` injectively so the dict-level oracle can verify it by
    value — growth, a missed reset, or cross-slot contamination all
    change the vector."""

    contract = "recurrent"

    def __init__(self, n_slots: int):
        super().__init__(n_slots)
        self.state = [self._zero() for _ in range(n_slots)]

    @staticmethod
    def _zero():
        return [0] * FAKE_STATE_SIZE

    def begin_admit(self, req, slot, prefix_cache=None):
        assert self.state[slot] == self._zero(), \
            f"admit into slot {slot} over stale recurrent state"
        super().begin_admit(req, slot, prefix_cache=prefix_cache)

    def _install(self, slot):
        super()._install(slot)
        s = self.slots[slot]
        # one wholesale scatter when the (possibly chunked) prefill ends
        self.state[slot] = [s.rid + 1, len(s.req.tokens) + 1] \
            + [0] * (FAKE_STATE_SIZE - 2)

    def decode_step(self):
        stepped = [i for i, s in enumerate(self.slots)
                   if not s.free and s.pending is None and s.remaining > 0]
        retired = super().decode_step()
        for i in stepped:                  # the one shared recurrent step
            self.state[i][1] += 1
        return retired

    def retire(self, slot):
        comp = super().retire(slot)
        self.state[slot] = self._zero()
        return comp

    def cancel(self, slot):
        partial = super().cancel(slot)
        self.state[slot] = self._zero()
        return partial


FAKES = {"kv": FakeEngine, "recurrent": RecurrentFakeEngine}


# ---------------------------------------------------------------------------
# mesh-sharded fakes: the slot cache as explicit per-device shards
# ---------------------------------------------------------------------------

FAKE_LEN = 16                      # preallocated fake kv length axis
SHARD_MESHES = ({"data": 2, "model": 2}, {"data": 1, "model": 3})


def shard_cell(rid: int, pos: int, mi: int) -> int:
    """The cell value request ``rid`` writes at kv position ``pos`` on
    model shard ``mi``. Injective in (rid, pos, mi): cross-slot AND
    cross-shard contamination both change it."""
    return (rid + 1) * 1000 + pos * 10 + (mi + 1)


class _ShardedFakeBase(FakeEngine):
    """``FakeEngine`` whose slot cache lives as explicit per-device shards
    over a dict mesh (same deviceless idiom as the ``slot_specs``
    doctests in ``repro.serve.sharding``). The slot axis splits over
    ``data`` only when divisible — otherwise every data shard replicates
    all slots, the production batch-1 rule — the payload splits over
    ``model`` (each model shard stores ``mi``-tagged cells), and the
    ``pos`` bookkeeping leaf is replicated on every device, mirroring
    ``REPLICATED_SLOT_LEAVES``. Writes and resets touch only the shards
    that own the slot; ``check_devices`` compares the whole device dict
    against the oracle's independent projection after every action."""

    def __init__(self, n_slots: int, mesh=None):
        super().__init__(n_slots)
        self.mesh = dict(mesh or SHARD_MESHES[0])
        d, m = self.mesh["data"], self.mesh["model"]
        self.spp = n_slots // d if n_slots % d == 0 else n_slots
        self.dev = {(di, mi): {"rows": [self._blank()
                                        for _ in range(self.spp)],
                               "pos": [0] * n_slots}
                    for di in range(d) for mi in range(m)}
        self._shapes = self._shape_map()

    def _shape_map(self):
        return {k: ([len(r) for r in v["rows"]], len(v["pos"]))
                for k, v in self.dev.items()}

    def _owner_devs(self, slot):
        """Yield ``((di, mi), local_row, mi)`` for every shard owning
        ``slot`` — one data shard when the slot axis divides, all of
        them when it is replicated."""
        d, m = self.mesh["data"], self.mesh["model"]
        if self.spp * d == len(self.slots):
            for mi in range(m):
                yield (slot // self.spp, mi), slot % self.spp, mi
        else:
            for di in range(d):
                for mi in range(m):
                    yield (di, mi), slot, mi

    def _pos(self, slot):
        return next(iter(self.dev.values()))["pos"][slot]

    def _set_pos(self, slot, p):
        for v in self.dev.values():         # replicated: every device
            v["pos"][slot] = p

    def _reset(self, slot):
        for key, row, _mi in self._owner_devs(slot):
            self.dev[key]["rows"][row] = self._blank()
        self._set_pos(slot, 0)

    def retire(self, slot):
        comp = super().retire(slot)
        self._reset(slot)
        return comp

    def cancel(self, slot):
        partial = super().cancel(slot)
        self._reset(slot)
        return partial

    def check_devices(self, oracle, n_slots):
        assert self._shape_map() == self._shapes, \
            "per-device shard shape drifted"
        expect = oracle.expected_device_state(n_slots, self.mesh,
                                              self.contract)
        assert self.dev == expect, \
            f"device shards diverged from oracle:\n{self.dev}\nvs\n{expect}"
        assert len(self.free_slots()) == len(oracle.free), \
            "free-slot capacity diverged from oracle"


class ShardedFakeEngine(_ShardedFakeBase):
    """kv contract: each shard row is a preallocated ``FAKE_LEN`` vector;
    admit scatters the prompt's cells, each decode writes exactly one new
    cell at the replicated ``pos`` cursor, retire/cancel zero the row on
    the owning shards only."""

    @staticmethod
    def _blank():
        return [0] * FAKE_LEN

    def _install(self, slot):
        super()._install(slot)
        s = self.slots[slot]
        plen = len(s.req.tokens)
        # chunk work is held aside: the prompt's cells land in ONE scatter
        # on the owning shards when the prefill completes (shard-local by
        # construction, exactly the real engine's write_slot)
        for key, row, mi in self._owner_devs(slot):
            r = self.dev[key]["rows"][row]
            assert r == self._blank(), \
                f"admit into slot {slot} over stale kv shard"
            for p in range(plen):
                r[p] = shard_cell(s.rid, p, mi)
        self._set_pos(slot, plen)

    def decode_step(self):
        stepped = [(i, s.rid) for i, s in enumerate(self.slots)
                   if not s.free and s.pending is None and s.remaining > 0]
        retired = super().decode_step()
        for slot, rid in stepped:           # one shared sharded scatter
            p = self._pos(slot)
            for key, row, mi in self._owner_devs(slot):
                r = self.dev[key]["rows"][row]
                assert r[p] == 0, f"kv cell {p} of slot {slot} overwritten"
                r[p] = shard_cell(rid, p, mi)
            self._set_pos(slot, p + 1)
        return retired


class ShardedRecurrentFakeEngine(_ShardedFakeBase):
    """Recurrent contract over the same mesh: fixed-width state vector
    per slot, written wholesale at admit, advanced by one shared step per
    decode, zeroed shard-locally at retire/cancel. Each model shard's
    vector carries its ``mi + 1`` tag so a write landing on the wrong
    shard is a value difference, not just a shape one."""

    contract = "recurrent"

    @staticmethod
    def _blank():
        return [0] * FAKE_STATE_SIZE

    def _install(self, slot):
        super()._install(slot)
        s = self.slots[slot]
        plen = len(s.req.tokens)
        for key, row, mi in self._owner_devs(slot):
            r = self.dev[key]["rows"][row]
            assert r == self._blank(), \
                f"admit into slot {slot} over stale recurrent shard"
            self.dev[key]["rows"][row] = [s.rid + 1, plen + 1, mi + 1] \
                + [0] * (FAKE_STATE_SIZE - 3)
        self._set_pos(slot, plen)

    def decode_step(self):
        stepped = [i for i, s in enumerate(self.slots)
                   if not s.free and s.pending is None and s.remaining > 0]
        retired = super().decode_step()
        for slot in stepped:                # the one shared recurrent step
            for key, row, _mi in self._owner_devs(slot):
                self.dev[key]["rows"][row][1] += 1
            self._set_pos(slot, self._pos(slot) + 1)
        return retired


SHARDED_FAKES = {"kv": ShardedFakeEngine,
                 "recurrent": ShardedRecurrentFakeEngine}


class ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# the oracle: an independent, dictionary-level model of the same semantics
# ---------------------------------------------------------------------------

class Oracle:
    """Slot-state oracle. Deliberately re-derived from docs/serving.md
    ("Front-end" section) rather than from frontend.py, with plain dicts:
    divergence between the two implementations fails the property."""

    def __init__(self, n_slots, depth, policy, chunk=None):
        self.depth, self.policy, self.chunk = depth, policy, chunk
        self.free = sorted(range(n_slots))
        self.queue = []                     # rids, arrival order
        self.running = {}                   # rid -> {slot, remaining, ntok,
                                            #         deadline, prefill}
                                            # prefill: prompt tokens left
                                            # before the first token exists
        self.final = {}                     # rid -> (status, ntok)
        self.reqs = {}                      # rid -> (gen, plen, deadline)
        self.admit_log = []

    def submit(self, rid, gen, plen, deadline, now):
        self.reqs[rid] = (gen, plen, deadline)
        if not self.queue and self.free:
            self._admit(rid, now)
        elif len(self.queue) < self.depth:
            self.queue.append(rid)
        else:
            self.final[rid] = ("rejected", 0)

    def _admit(self, rid, now):
        gen, plen, dl = self.reqs[rid]
        if dl is not None and now >= dl:    # dead on arrival: no work
            self.final[rid] = ("expired", 0)
            return
        self.admit_log.append(rid)
        slot = self.free.pop(0)
        if self.chunk is not None and plen > self.chunk:
            # chunked admit: one chunk now, the slot is PREFILLING —
            # occupied, zero tokens, skipped by decode
            self.running[rid] = dict(slot=slot, remaining=gen, ntok=0,
                                     deadline=dl, prefill=plen - self.chunk)
        elif gen == 1:                      # completes at admit
            self.final[rid] = ("done", 1)
            self.free = sorted(self.free + [slot])
        else:
            self.running[rid] = dict(slot=slot, remaining=gen - 1,
                                     ntok=1, deadline=dl, prefill=0)

    def cancel(self, rid):
        if rid in self.final:
            return
        if rid in self.queue:
            self.queue.remove(rid)
            self.final[rid] = ("cancelled", 0)
        elif rid in self.running:
            r = self.running.pop(rid)
            self.free = sorted(self.free + [r["slot"]])
            self.final[rid] = ("cancelled", r["ntok"])

    def _pop_queue(self):
        if self.policy == "spf":
            i = min(range(len(self.queue)),
                    key=lambda j: self.reqs[self.queue[j]][1])
        else:
            i = 0
        return self.queue.pop(i)

    def step(self, now):
        for rid in [q for q in self.queue
                    if self.reqs[q][2] is not None
                    and self.reqs[q][2] <= now]:
            self.queue.remove(rid)
            self.final[rid] = ("expired", 0)
        for rid, r in [(k, v) for k, v in self.running.items()
                       if v["deadline"] is not None
                       and now >= v["deadline"]]:
            del self.running[rid]
            self.free = sorted(self.free + [r["slot"]])
            # expiry mid-chunked-prefill keeps ZERO tokens (partial
            # prefill discarded); ntok is 0 exactly then
            self.final[rid] = ("expired", r["ntok"])
        # resume chunked prefills, slot order; a prompt that completes
        # joins this same step's decode; gen==1 frees its slot before
        # the refill below
        for rid in sorted((k for k, v in self.running.items()
                           if v["prefill"]),
                          key=lambda k: self.running[k]["slot"]):
            r = self.running[rid]
            r["prefill"] = max(0, r["prefill"] - self.chunk)
            if r["prefill"] == 0:
                r["ntok"], r["remaining"] = 1, self.reqs[rid][0] - 1
                if r["remaining"] == 0:
                    del self.running[rid]
                    self.free = sorted(self.free + [r["slot"]])
                    self.final[rid] = ("done", 1)
        while self.queue and self.free:
            self._admit(self._pop_queue(), now)
        retired = []
        for rid, r in self.running.items():
            if r["prefill"]:
                continue                    # PREFILLING: no decode lane
            r["ntok"] += 1
            r["remaining"] -= 1
            if r["remaining"] == 0:
                retired.append(rid)
        for rid in retired:
            r = self.running.pop(rid)
            self.free = sorted(self.free + [r["slot"]])
            self.final[rid] = ("done", r["ntok"])

    def expected_state(self, n_slots):
        """Recurrent-contract projection of the oracle's own dicts: what
        every slot's fixed-size state vector must be *right now* — zeros
        when free (reset on retire/cancel/expiry), ``(rid + 1,
        plen + ntok)`` while occupied. Derived without ever looking at
        the engine, so a missed reset, state growth, or cross-slot
        contamination in the engine fails the comparison."""
        state = [[0] * FAKE_STATE_SIZE for _ in range(n_slots)]
        for rid, r in self.running.items():
            if r["prefill"]:
                continue    # mid-chunked-prefill: nothing scattered yet
            state[r["slot"]] = [rid + 1, self.reqs[rid][1] + r["ntok"]] \
                + [0] * (FAKE_STATE_SIZE - 2)
        return state

    def expected_device_state(self, n_slots, mesh, kind):
        """Mesh-sharded projection of the oracle's dicts: the exact
        per-device shard dict a sharded fake must hold *right now*.
        Re-derives the ownership rule independently (slot axis over
        ``data`` only when divisible, else replicated; payload over
        ``model``; ``pos`` replicated everywhere): free slots are zeros
        on every shard, an occupied slot's cells exist only on its
        owners, kv rows carry ``shard_cell(rid, p, mi)`` for the
        ``plen + ntok - 1`` filled positions, recurrent rows carry
        ``[rid + 1, plen + ntok, mi + 1, 0...]``."""
        d, m = mesh["data"], mesh["model"]
        spp = n_slots // d if n_slots % d == 0 else n_slots
        width = FAKE_LEN if kind == "kv" else FAKE_STATE_SIZE
        # a PREFILLING slot holds its chunk work aside: its shards stay
        # blank (and pos 0) until the install scatter
        occ = {r["slot"]: (rid, self.reqs[rid][1], r["ntok"])
               for rid, r in self.running.items() if not r["prefill"]}
        pos = [occ[s][1] + occ[s][2] - 1 if s in occ else 0
               for s in range(n_slots)]
        dev = {}
        for di in range(d):
            slots = (range(di * spp, (di + 1) * spp)
                     if spp * d == n_slots else range(n_slots))
            for mi in range(m):
                rows = []
                for s in slots:
                    if s not in occ:
                        rows.append([0] * width)
                    elif kind == "kv":
                        rid, plen, ntok = occ[s]
                        filled = plen + ntok - 1
                        rows.append([shard_cell(rid, p, mi)
                                     if p < filled else 0
                                     for p in range(width)])
                    else:
                        rid, plen, ntok = occ[s]
                        rows.append([rid + 1, plen + ntok, mi + 1]
                                    + [0] * (width - 3))
                dev[(di, mi)] = {"rows": rows, "pos": list(pos)}
        return dev


# ---------------------------------------------------------------------------
# random-sequence driver
# ---------------------------------------------------------------------------

STATUS_NAME = {Status.DONE: "done", Status.REJECTED: "rejected",
               Status.EXPIRED: "expired", Status.CANCELLED: "cancelled"}


def _run_sequence(seed, n_slots, depth, policy, n_actions=18,
                  deadline_prob=0.35, engine_cls=FakeEngine, chunk=None):
    """Drive frontend (production code, FakeEngine) and oracle through the
    same random action sequence; return both plus instrumentation.
    ``chunk`` turns on the scheduler's chunked-prefill policy — the oracle
    models the resulting non-atomic admit lifecycle independently."""
    rng = random.Random(seed)
    eng = engine_cls(n_slots)
    clk = ManualClock()
    fe = ServeFrontend(eng, queue_depth=depth, policy=policy, clock=clk,
                       prefill_chunk=chunk)
    oracle = Oracle(n_slots, depth, policy, chunk=chunk)

    terminal_log = []                       # (rid, status) exactly-once log
    orig_finish = fe._finish

    def logged_finish(h, status):
        terminal_log.append((h.rid, status))
        orig_finish(h, status)

    fe._finish = logged_finish

    # spy on begin_admit: atomic admit() delegates to it, so this fires
    # exactly once per admission in BOTH the atomic and chunked modes
    admit_log = []                          # engine-admitted rids, in order
    orig_begin = eng.begin_admit

    def logged_begin(req, slot, prefix_cache=None):
        admit_log.append(req.rid)
        orig_begin(req, slot, prefix_cache=prefix_cache)

    eng.begin_admit = logged_begin

    rid = 0
    for _ in range(n_actions):
        act = rng.choices(("submit", "step", "advance", "cancel"),
                          weights=(5, 3, 2, 1))[0]
        if act == "submit":
            gen = rng.randint(1, 5)
            plen = rng.randint(1, 8)
            deadline = (clk.t + rng.uniform(0.0, 6.0)
                        if rng.random() < deadline_prob else None)
            req = Request(rid=rid, tokens=np.arange(plen, dtype=np.int32),
                          gen=gen, deadline=deadline)
            fe.submit(req)
            oracle.submit(rid, gen, plen, deadline, clk.t)
            rid += 1
        elif act == "step":
            fe.step()
            oracle.step(clk.t)
        elif act == "advance":
            clk.advance(rng.uniform(0.5, 3.0))
        else:
            if rid:
                victim = rng.randrange(rid)
                fe.cancel(victim)
                oracle.cancel(victim)
        assert len(fe._by_slot) <= n_slots
        if eng.contract == "recurrent" and hasattr(eng, "state"):
            # the recurrent-state contract, checked after EVERY action:
            # constant size, reset on retire/cancel/expiry, no cross-slot
            # contamination (the oracle projects the expected vectors)
            assert eng.state == oracle.expected_state(n_slots)
        if hasattr(eng, "check_devices"):
            # the sharded contract, checked after EVERY action: shard
            # shapes invariant, cells only on owning shards, replicated
            # pos in agreement, capacity parity with the oracle
            eng.check_devices(oracle, n_slots)

    # drain: no deadline outlives a big jump, so every survivor terminates
    clk.advance(1e6)
    for _ in range(64):
        busy = fe.step()
        oracle.step(clk.t)
        if eng.contract == "recurrent" and hasattr(eng, "state"):
            assert eng.state == oracle.expected_state(n_slots)
        if hasattr(eng, "check_devices"):
            eng.check_devices(oracle, n_slots)
        if not busy:
            break
    else:                                   # pragma: no cover - deadlock
        raise AssertionError("front-end failed to drain in 64 steps")
    return fe, eng, oracle, terminal_log, admit_log


def _check_invariants(fe, eng, oracle, terminal_log, admit_log):
    # -- no slot leak, queue drained
    assert all(s.free for s in eng.slots)
    assert not fe._by_slot and len(fe.queue) == 0

    # -- exactly one terminal transition per request
    rids = [r for r, _ in terminal_log]
    assert sorted(rids) == sorted(set(rids)), \
        f"double terminal transition: {terminal_log}"
    assert sorted(rids) == sorted(fe.handles), \
        "some request never reached a terminal state"

    # -- admission order parity with the oracle
    assert admit_log == oracle.admit_log, \
        f"admit order diverged: {admit_log} vs oracle {oracle.admit_log}"

    for rid, h in fe.handles.items():
        assert h.finished, f"rid {rid} left in {h.status}"
        status, ntok = oracle.final[rid]
        assert STATUS_NAME[h.status] == status, \
            (f"rid {rid}: frontend {h.status} vs oracle {status}")
        assert len(h.tokens) == ntok, \
            (f"rid {rid}: {len(h.tokens)} tokens vs oracle {ntok}")
        # -- attribution: tokens are exactly rid's own stream prefix
        assert h.tokens == [fake_token(rid, i)
                            for i in range(len(h.tokens))], \
            f"rid {rid}: contaminated tokens {h.tokens}"
        if h.status is Status.DONE:
            assert len(h.tokens) == h.req.gen
        elif h.status is Status.REJECTED:
            assert h.tokens == []
            assert isinstance(h.result, Overloaded)
            assert h.result.queue_depth == fe.queue.depth
        else:                               # EXPIRED / CANCELLED
            assert len(h.tokens) < h.req.gen


@settings(max_examples=60)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       n_slots=st.integers(min_value=1, max_value=3),
       depth=st.integers(min_value=0, max_value=4),
       policy=st.sampled_from(("fifo", "spf")),
       fake=st.sampled_from(("kv", "recurrent")),
       chunk=st.sampled_from((None, 1, 2, 3)))
def test_slot_lifecycle_matches_oracle(seed, n_slots, depth, policy, fake,
                                       chunk):
    """>= 50 random action sequences: production scheduler == oracle,
    under both slot-cache contracts (the recurrent fake additionally
    checks its state vectors against the oracle after every action) and
    under both atomic admits and the scheduler's chunked-prefill policy
    (the oracle models the non-atomic PREFILLING lifecycle: no decode
    lane until the prompt is consumed, zero tokens on mid-prefill expiry
    or cancel, no slot leaks, exactly-once terminals)."""
    _check_invariants(*_run_sequence(seed, n_slots, depth, policy,
                                     engine_cls=FAKES[fake], chunk=chunk))


@settings(max_examples=60)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       n_slots=st.integers(min_value=1, max_value=3),
       depth=st.integers(min_value=0, max_value=4),
       policy=st.sampled_from(("fifo", "spf")),
       fake=st.sampled_from(("kv", "recurrent")),
       mesh_i=st.sampled_from((0, 1)),
       chunk=st.sampled_from((None, 2)))
def test_sharded_slot_cache_matches_device_oracle(seed, n_slots, depth,
                                                  policy, fake, mesh_i,
                                                  chunk):
    """>= 60 random action sequences against the mesh-sharded fakes: the
    full per-device shard dict equals the oracle's projection after every
    single action (shard-shape invariance, owner-only writes, shard-local
    resets, replicated pos parity, capacity parity), under both slot-
    cache contracts and both a (2 data x 2 model) and a model-only mesh.
    n_slots in 1..3 over data=2 covers the divisible-slot-axis split AND
    the replicated batch-1 rule. With ``chunk`` set, a PREFILLING slot's
    shards must stay blank until the single install scatter — chunk
    writes land shard-local, all at once, never early."""
    mesh = SHARD_MESHES[mesh_i]
    _check_invariants(*_run_sequence(
        seed, n_slots, depth, policy,
        engine_cls=lambda n: SHARDED_FAKES[fake](n, mesh=mesh),
        chunk=chunk))


def test_sharded_fake_owner_only_writes_and_local_reset():
    """Unit pin of the sharded-fake mechanics the property relies on:
    admitting into slot 1 of a 2-slot cache on a (2, 2) mesh touches ONLY
    data shard 1's rows, the two model shards hold distinct mi-tagged
    cells for the same position, pos is replicated on all four devices,
    and retire zeros the owning shards without disturbing the others."""
    eng = ShardedFakeEngine(2, mesh={"data": 2, "model": 2})
    req = Request(rid=7, tokens=np.arange(3, dtype=np.int32), gen=2)
    eng.admit(req, 1)
    for mi in range(2):
        assert eng.dev[(0, mi)]["rows"] == [[0] * FAKE_LEN]   # untouched
        row = eng.dev[(1, mi)]["rows"][0]
        assert row[:3] == [shard_cell(7, p, mi) for p in range(3)]
        assert row[3:] == [0] * (FAKE_LEN - 3)
    assert eng.dev[(0, 0)]["rows"][0] != eng.dev[(1, 0)]["rows"][0]
    assert eng.dev[(1, 0)]["rows"][0] != eng.dev[(1, 1)]["rows"][0]
    assert all(v["pos"] == [0, 3] for v in eng.dev.values())
    eng.decode_step()                       # one more cell at pos 3
    assert all(v["pos"] == [0, 4] for v in eng.dev.values())
    assert eng.dev[(1, 1)]["rows"][0][3] == shard_cell(7, 3, 1)
    eng.retire(1)                           # shard-local zero-reset
    blank = [0] * FAKE_LEN
    assert all(v["rows"] == [blank] and v["pos"] == [0, 0]
               for v in eng.dev.values())
    # non-divisible slot count: every data shard replicates all slots
    rep = ShardedRecurrentFakeEngine(3, mesh={"data": 2, "model": 2})
    assert all(len(v["rows"]) == 3 for v in rep.dev.values())
    rep.admit(Request(rid=0, tokens=np.arange(2, dtype=np.int32), gen=3), 2)
    for di in range(2):                     # replicated: both data shards
        for mi in range(2):
            assert rep.dev[(di, mi)]["rows"][2][:3] == [1, 3, mi + 1]
    rep.cancel(2)
    assert all(r == [0] * FAKE_STATE_SIZE
               for v in rep.dev.values() for r in v["rows"])


@settings(max_examples=60)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       n_slots=st.integers(min_value=1, max_value=4),
       depth=st.integers(min_value=0, max_value=6))
def test_burst_admission_counts(seed, n_slots, depth):
    """All-at-once burst: accepted == slots + depth, rest rejected with a
    typed Overloaded, and every accepted request completes. (gen >= 2 so
    no request completes *at admit* and frees its slot mid-burst — that
    legitimately raises the accept count.)"""
    rng = random.Random(seed)
    n = n_slots + depth + rng.randint(1, 6)
    eng = FakeEngine(n_slots)
    clk = ManualClock()
    fe = ServeFrontend(eng, queue_depth=depth, clock=clk)
    hs = [fe.submit(Request(rid=i,
                            tokens=np.arange(rng.randint(1, 6),
                                             dtype=np.int32),
                            gen=rng.randint(2, 4)))
          for i in range(n)]
    rejected = [h for h in hs if h.status is Status.REJECTED]
    assert len(rejected) == n - n_slots - depth
    assert all(isinstance(h.result, Overloaded) for h in rejected)
    for _ in range(256):
        if not fe.step():
            break
    for h in hs:
        if h not in rejected:
            assert h.status is Status.DONE
            assert len(h.tokens) == h.req.gen


@settings(max_examples=60)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_fifo_queue_admits_in_submit_order(seed):
    """Single slot, FIFO: queued requests are admitted strictly in submit
    order (checked via FakeEngine's admit counter)."""
    rng = random.Random(seed)
    eng = FakeEngine(1)
    fe = ServeFrontend(eng, queue_depth=8, clock=ManualClock())
    order = []
    real_admit = eng.admit

    def spy(req, slot, prefix_cache=None):
        order.append(req.rid)
        real_admit(req, slot, prefix_cache=prefix_cache)

    eng.admit = spy
    n = rng.randint(3, 8)
    for i in range(n):
        fe.submit(Request(rid=i, tokens=np.arange(2, dtype=np.int32),
                          gen=rng.randint(2, 4)))
    while fe.step():
        pass
    assert order == sorted(order) == list(range(min(n, 1 + 8)))


def test_spf_prefers_short_prompts():
    """spf pops the shortest waiting prompt; FIFO pops arrival order."""
    for policy, expect in (("fifo", [0, 1, 2, 3]), ("spf", [0, 3, 2, 1])):
        eng = FakeEngine(1)
        fe = ServeFrontend(eng, queue_depth=8, policy=policy,
                           clock=ManualClock())
        order = []
        real_admit = eng.admit
        eng.admit = (lambda req, slot, prefix_cache=None:
                     (order.append(req.rid),
                      real_admit(req, slot, prefix_cache=prefix_cache)))
        for rid, plen in enumerate((2, 8, 5, 3)):   # rid 0 admits directly
            fe.submit(Request(rid=rid,
                              tokens=np.arange(plen, dtype=np.int32),
                              gen=2))
        while fe.step():
            pass
        assert order == expect, (policy, order)


def test_double_finish_is_an_error():
    """_finish asserts exactly-once terminal transitions."""
    eng = FakeEngine(1)
    fe = ServeFrontend(eng, queue_depth=2, clock=ManualClock())
    h = fe.submit(Request(rid=0, tokens=np.arange(2, dtype=np.int32),
                          gen=2))
    while fe.step():
        pass
    assert h.status is Status.DONE
    with pytest.raises(AssertionError, match="finalized twice"):
        fe._finish(h, Status.CANCELLED)


# ---------------------------------------------------------------------------
# synthetic_trace seed-determinism contract (per-field substreams)
# ---------------------------------------------------------------------------

def _trace_fields(reqs):
    return dict(
        prompts=[r.tokens.tolist() for r in reqs],
        gens=[r.gen for r in reqs],
        arrivals=[r.arrival for r in reqs],
        deadlines=[r.deadline for r in reqs],
    )


@settings(max_examples=50)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_trace_seed_fully_determinizes(seed):
    """Same seed + same kwargs => identical trace, every field (prompt
    tokens, gens, Poisson arrival gaps, deadlines)."""
    from repro.serve import synthetic_trace
    kw = dict(prompt_range=(4, 12), gen_range=(2, 8), rate=25.0,
              deadline_range=(0.1, 2.0), deadline_frac=0.7)
    a = _trace_fields(synthetic_trace(12, 101, seed=seed, **kw))
    b = _trace_fields(synthetic_trace(12, 101, seed=seed, **kw))
    assert a == b


@settings(max_examples=50)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_trace_fields_draw_from_independent_substreams(seed):
    """The regression the substream fix pins: toggling one knob must not
    reshuffle the draws of an unrelated field (one shared RNG stream used
    to couple every field through global draw order)."""
    from repro.serve import synthetic_trace
    base = dict(prompt_range=(4, 12), gen_range=(2, 8))
    plain = synthetic_trace(10, 101, seed=seed, **base)
    # turning Poisson arrivals on must not change lengths or tokens
    timed = synthetic_trace(10, 101, seed=seed, rate=30.0, **base)
    assert [r.tokens.tolist() for r in timed] == \
           [r.tokens.tolist() for r in plain]
    assert [r.gen for r in timed] == [r.gen for r in plain]
    # adding deadlines must not perturb the arrival timeline
    dl = synthetic_trace(10, 101, seed=seed, rate=30.0,
                         deadline_range=(0.1, 1.0), **base)
    assert [r.arrival for r in dl] == [r.arrival for r in timed]
    # the deadline *mix* knob must not change surviving deadline values:
    # budgets are drawn unconditionally, the frac only masks them
    dl_all = synthetic_trace(10, 101, seed=seed, rate=30.0,
                             deadline_range=(0.1, 1.0),
                             deadline_frac=1.0, **base)
    for sparse, dense in zip(dl, dl_all):
        if sparse.deadline is not None:
            assert sparse.deadline == dense.deadline


def test_trace_prefix_len_prepends_shared_block():
    """prefix_len prepends one shared system prompt; prompt_range sizes
    the per-request suffix only."""
    from repro.serve import synthetic_trace
    reqs = synthetic_trace(6, 101, seed=9, prompt_range=(3, 7),
                           prefix_len=16)
    first = reqs[0].tokens[:16].tolist()
    for r in reqs:
        assert r.tokens[:16].tolist() == first
        assert 3 <= len(r.tokens) - 16 <= 7
    # suffixes differ (vocab 101, 3+ tokens: collision would be rare)
    assert len({r.tokens[16:].tobytes() for r in reqs}) > 1


# ---------------------------------------------------------------------------
# unit coverage of the pure scheduling datastructures
# ---------------------------------------------------------------------------

def test_admission_queue_validation_and_removal():
    from repro.serve.queue import AdmissionQueue
    with pytest.raises(ValueError, match="depth"):
        AdmissionQueue(-1)
    with pytest.raises(ValueError, match="policy"):
        AdmissionQueue(2, policy="lifo")
    q = AdmissionQueue(2)
    with pytest.raises(IndexError):
        q.pop()

    class Item:
        prompt_len, deadline = 1, None

    a, b = Item(), Item()
    assert q.push(a) and list(q) == [a]
    assert not q.remove(b)                  # b was never queued
    assert q.remove(a) and len(q) == 0
    assert q.push(a) and q.push(b) and q.full
    assert not q.push(Item())               # bounded: refused, no effect
    assert len(q) == 2


# ---------------------------------------------------------------------------
# fleet properties: ReplicaRouter vs an independent fleet oracle
# ---------------------------------------------------------------------------
#
# The router speaks the same engine-agnostic slot surface it consumes, so
# the production router code is driven directly (a thin admission loop
# standing in for the front-end's ``free_slots()[0]`` choice) over
# pure-Python ``FleetFakeEngine`` replicas, against a dict-level fleet
# oracle re-derived from docs/serving.md ("Multi-replica routing").
# Invariants per sequence: exactly-once terminal status across replicas,
# least-loaded admit parity with the oracle argmin (tie-break by replica
# index), no slot leaks on any live replica, and no cross-replica token
# contamination (fleet_token attribution).

from repro.serve import ReplicaRouter, ReplicaState  # noqa: E402
from repro.serve.testing import (FleetFakeEngine,  # noqa: E402
                                 RecurrentFleetFakeEngine, fleet_token)

FLEET_FAKES = {"kv": FleetFakeEngine, "recurrent": RecurrentFleetFakeEngine}


class FleetOracle:
    """Dict-level model of the fleet scheduler: least-loaded argmin with
    replica-index tie-break, FIFO re-dispatch of orphans (ordered by
    virtual slot id, as the router's orphan scan is), FAILED only when no
    UP replica remains, draining replicas excluded from admission."""

    def __init__(self, n_replicas, slots_per):
        self.state = ["up"] * n_replicas
        self.cap = [slots_per] * n_replicas
        self.occ = [0] * n_replicas
        self.running = {}     # rid -> {replica|None, ntok, remaining, gid}
        self.pending = []     # [(gid, rid)] FIFO
        self.final = {}       # rid -> (status, ntok)
        self.admit_log = []   # (rid, replica), fresh admits + re-dispatches

    def capacity(self):
        free = sum(self.cap[i] - self.occ[i]
                   for i in range(len(self.cap)) if self.state[i] == "up")
        return max(0, free - len(self.pending))

    def _argmin(self):
        cand = [i for i in range(len(self.cap))
                if self.state[i] == "up" and self.occ[i] < self.cap[i]]
        return min(cand, key=lambda i: (self.occ[i], i)) if cand else None

    def submit(self, rid, gen, gid):
        i = self._argmin()
        self.admit_log.append((rid, i))
        if gen == 1:                        # completes at admit
            self.final[rid] = ("done", 1)
            return
        self.occ[i] += 1
        self.running[rid] = dict(replica=i, ntok=1, remaining=gen - 1,
                                 gid=gid)

    def cancel(self, rid):
        if rid in self.final:
            return
        r = self.running.pop(rid)
        if r["replica"] is not None:
            self.occ[r["replica"]] -= 1
        else:
            self.pending = [(g, q) for g, q in self.pending if q != rid]
        self.final[rid] = ("cancelled", r["ntok"])

    def kill(self, i):
        if self.state[i] == "down":
            return
        self.state[i] = "down"
        orphans = sorted(
            (rid for rid, r in self.running.items() if r["replica"] == i),
            key=lambda rid: self.running[rid]["gid"])
        for rid in orphans:
            self.running[rid]["replica"] = None
            self.pending.append((self.running[rid]["gid"], rid))
        self.occ[i] = 0

    def drain(self, i):
        if self.state[i] == "up":
            self.state[i] = "draining"

    def step(self):
        while self.pending:                 # re-dispatch, FIFO
            gid, rid = self.pending[0]
            if not any(s == "up" for s in self.state):
                self.pending.pop(0)
                r = self.running.pop(rid)
                self.final[rid] = ("failed", r["ntok"])
                continue
            i = self._argmin()
            if i is None:
                break                       # survivors busy: keep waiting
            self.pending.pop(0)
            self.admit_log.append((rid, i))
            self.running[rid]["replica"] = i
            self.occ[i] += 1
        done = []
        for rid, r in self.running.items():
            if r["replica"] is None:
                continue
            r["ntok"] += 1
            r["remaining"] -= 1
            if r["remaining"] == 0:
                done.append(rid)
        for rid in done:
            r = self.running.pop(rid)
            self.occ[r["replica"]] -= 1
            self.final[rid] = ("done", r["ntok"])


def _run_fleet_sequence(seed, n_replicas, slots_per, n_actions=22,
                        engine_cls=FleetFakeEngine):
    """Drive the production ReplicaRouter and the fleet oracle through the
    same random submit/step/cancel/kill/drain sequence."""
    rng = random.Random(seed)
    engines = [engine_cls(slots_per) for _ in range(n_replicas)]
    router = ReplicaRouter(engines)
    oracle = FleetOracle(n_replicas, slots_per)

    admit_log = []                          # (rid, replica), success order
    for ri, e in enumerate(engines):
        def spy(req, slot, prefix_cache=None, _orig=e.admit, _ri=ri):
            _orig(req, slot, prefix_cache=prefix_cache)
            admit_log.append((req.rid, _ri))
        e.admit = spy

    record = {}                             # rid -> {gid,status,tokens,gen}
    gid_rid = {}                            # live gid -> rid

    def finish(r_id, status, tokens):
        rec = record[r_id]
        assert rec["status"] is None, f"double terminal for rid {r_id}"
        rec["status"], rec["tokens"] = status, [int(t) for t in tokens]
        gid_rid.pop(rec["gid"], None)

    def do_step():
        for gid in router.decode_step():
            comp = router.retire(gid)
            finish(gid_rid[gid], "done", comp.tokens)
        for gid, toks in router.take_failed():
            finish(gid_rid[gid], "failed", toks)
        oracle.step()

    rid = 0
    for _ in range(n_actions):
        act = rng.choices(("submit", "step", "cancel", "kill", "drain"),
                          weights=(5, 4, 1, 1, 1))[0]
        if act == "submit":
            free = router.free_slots()
            if not free:
                assert oracle.capacity() == 0
                continue
            gid = free[0]                   # the front-end's choice
            gen, plen = rng.randint(1, 5), rng.randint(1, 6)
            record[rid] = dict(gid=gid, status=None, tokens=None, gen=gen)
            gid_rid[gid] = rid
            router.admit(Request(rid=rid,
                                 tokens=np.arange(plen, dtype=np.int32),
                                 gen=gen), gid)
            oracle.submit(rid, gen, gid)
            if router.slots[gid].remaining == 0:    # gen==1 instant done
                finish(rid, "done", router.retire(gid).tokens)
            rid += 1
        elif act == "step":
            do_step()
        elif act == "cancel":
            if not rid:
                continue
            victim = rng.randrange(rid)
            if record[victim]["status"] is None:
                finish(victim, "cancelled",
                       router.cancel(record[victim]["gid"]))
            oracle.cancel(victim)
        elif act == "kill":
            i = rng.randrange(n_replicas)
            router.kill(i)
            oracle.kill(i)
        else:
            i = rng.randrange(n_replicas)
            router.drain(i)
            oracle.drain(i)
        assert len(router.free_slots()) == oracle.capacity(), \
            "fleet capacity diverged from oracle"
        if engine_cls.contract == "recurrent":
            for e in engines:               # per-replica state contract
                e.check_state()

    for _ in range(300):                    # drain every survivor
        if router.active_count() == 0:
            break
        do_step()
        if engine_cls.contract == "recurrent":
            for e in engines:
                e.check_state()
    else:                                   # pragma: no cover - deadlock
        raise AssertionError("fleet failed to drain in 300 steps")
    return router, engines, oracle, record, admit_log


def _check_fleet_invariants(router, engines, oracle, record, admit_log):
    # -- no slot leak on any live replica; every virtual slot released
    for rep, e in zip(router.replicas, engines):
        if rep.state is not ReplicaState.DOWN:
            assert all(s.free for s in e.slots), "physical slot leak"
    assert all(v.free for v in router.vslots), "virtual slot leak"
    assert not router._pending and not router._failed

    # -- least-loaded parity: every admit (fresh + re-dispatch) landed on
    #    the oracle's argmin replica, in the same order
    assert admit_log == oracle.admit_log, \
        f"routing diverged: {admit_log} vs oracle {oracle.admit_log}"

    # -- exactly one terminal per request, matching the oracle
    assert set(record) == set(oracle.final)
    for rid, rec in record.items():
        status, ntok = oracle.final[rid]
        assert rec["status"] == status, \
            (f"rid {rid}: router {rec['status']} vs oracle {status}")
        assert len(rec["tokens"]) == ntok, \
            (f"rid {rid}: {len(rec['tokens'])} tokens vs oracle {ntok}")
        # -- attribution: exactly rid's own stream, no cross-replica mix
        assert rec["tokens"] == [fleet_token(rid, i) for i in range(ntok)],\
            f"rid {rid}: contaminated tokens {rec['tokens']}"
        if status == "done":
            assert ntok == rec["gen"]

    # -- a draining replica with nothing in flight reports removable
    for i, rep in enumerate(router.replicas):
        if rep.state is ReplicaState.DRAINING:
            assert router.drained(i)


@settings(max_examples=60)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       n_replicas=st.integers(min_value=1, max_value=3),
       slots_per=st.integers(min_value=1, max_value=2),
       fake=st.sampled_from(("kv", "recurrent")))
def test_fleet_lifecycle_matches_oracle(seed, n_replicas, slots_per, fake):
    """>= 60 random submit/step/cancel/kill/drain sequences: production
    router == fleet oracle (statuses, token counts, routing argmin),
    under both slot-cache contracts; the recurrent fleet fake checks its
    per-slot state vectors (constant size, reset on retire/cancel, no
    cross-slot contamination) after every action."""
    _check_fleet_invariants(
        *_run_fleet_sequence(seed, n_replicas, slots_per,
                             engine_cls=FLEET_FAKES[fake]))


def test_recurrent_fake_resets_and_rejects_stale_state():
    """Unit pin of the recurrent contract the fakes enforce: admit
    scatters state, each decode advances it by exactly one, retire and
    cancel zero it, and an admit over un-reset state is an error."""
    eng = RecurrentFleetFakeEngine(2)
    eng.admit(Request(rid=0, tokens=np.arange(3, dtype=np.int32), gen=3), 0)
    eng.check_state()
    assert eng.state[0][:2] == [1, 4] and eng.state[1] == eng._zero()
    eng.decode_step()
    assert eng.state[0][:2] == [1, 5]
    eng.check_state()
    eng.decode_step()
    eng.retire(0)
    assert eng.state[0] == eng._zero()      # reset, not dangling
    eng.admit(Request(rid=1, tokens=np.arange(2, dtype=np.int32), gen=4), 1)
    eng.cancel(1)
    assert eng.state[1] == eng._zero()
    eng.check_state()
    eng.state[0] = [9, 9, 0, 0]             # simulate a missed reset
    with pytest.raises(AssertionError, match="stale"):
        eng.admit(Request(rid=2, tokens=np.arange(2, dtype=np.int32),
                          gen=2), 0)


def test_least_loaded_tie_breaks_by_replica_index():
    """Equal load routes to the lowest replica index, deterministically."""
    engines = [FleetFakeEngine(2) for _ in range(3)]
    router = ReplicaRouter(engines)
    landed = []
    for ri, e in enumerate(engines):
        def spy(req, slot, prefix_cache=None, _orig=e.admit, _ri=ri):
            _orig(req, slot, prefix_cache=prefix_cache)
            landed.append(_ri)
        e.admit = spy
    for rid in range(6):
        router.admit(Request(rid=rid, tokens=np.arange(3, dtype=np.int32),
                             gen=4), router.free_slots()[0])
    # round-robin by load: ties always resolve to the lowest index
    assert landed == [0, 1, 2, 0, 1, 2]


def test_prefix_cache_validation_refresh_and_stats():
    from repro.serve.prefix import PrefixCache, common_prefix_len
    with pytest.raises(ValueError, match="cap"):
        PrefixCache(cap=0)
    assert common_prefix_len(np.empty(0, np.int32),
                             np.arange(3, dtype=np.int32)) == 0
    pc = PrefixCache(cap=2, min_hit=2)
    t = np.arange(6, dtype=np.int32)
    pc.insert(t, cache="c", nbytes=10)
    pc.insert(t, cache="c2", nbytes=99)     # duplicate: refresh, keep first
    assert len(pc) == 1 and pc.bytes == 10
    hit = pc.lookup(np.concatenate([t[:4], np.array([9], np.int32)]))
    assert hit is not None and hit[1] == 4
    assert pc.lookup(np.array([8, 8, 8], np.int32)) is None   # miss
    s = pc.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["entries"] == 1
