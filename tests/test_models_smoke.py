"""Per-architecture smoke tests (assignment requirement (f)).

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward + one train step on CPU, asserting output shapes and
finiteness. The FULL configs are exercised only via the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update

from helpers import batch_for, tiny_cfg


@pytest.mark.parametrize("arch", ARCH_IDS + ("deit-base",))
def test_smoke_forward_and_train_step(arch):
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = batch_for(cfg, B=2, T=16)

    out = model.apply(params, batch)
    y = out[0] if isinstance(out, tuple) else out
    B = batch.get("tokens", batch.get("images")).shape[0]
    if cfg.family == "vit":
        assert y.shape == (B, cfg.n_classes)
    else:
        assert y.shape[0] == B and y.shape[-1] == cfg.padded_vocab
    assert np.all(np.isfinite(np.asarray(y, np.float32)))

    # one train step
    ocfg = AdamWConfig()
    opt = adamw_init(params, ocfg)
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert np.isfinite(float(loss))
    new_p, new_o, m = adamw_update(params, grads, opt, 1e-3, ocfg)
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    d = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)))
    assert d > 0


@pytest.mark.parametrize("arch", ["granite-8b", "gemma3-1b", "rwkv6-3b",
                                  "jamba-1.5-large-398b",
                                  "deepseek-v3-671b",
                                  "seamless-m4t-large-v2"])
def test_smoke_decode_matches_forward(arch):
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, 8, cfg.d_model))
    full = model.apply(params, batch)[0]
    pre = dict(batch, tokens=toks[:, :T - 2])
    lg, cache = model.prefill(params, pre, T + 4)
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(full[:, T - 3]), rtol=2e-3,
                               atol=2e-3)
    for t in range(T - 2, T):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(lg[:, -1]),
                                   np.asarray(full[:, t]), rtol=2e-3,
                                   atol=2e-3)


def test_full_config_dims_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }
    for arch, (L, D, H, KV, F, V) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, D, H, KV, F, V), arch
    # structural features
    assert get_config("deepseek-v3-671b").moe.num_experts == 256
    assert get_config("deepseek-v3-671b").moe.top_k == 8
    assert get_config("deepseek-v3-671b").mla is not None
    assert get_config("qwen3-moe-235b-a22b").moe.num_experts == 128
    assert get_config("jamba-1.5-large-398b").moe.num_experts == 16
    assert get_config("jamba-1.5-large-398b").pattern.count("mamba") == 7
    assert get_config("gemma3-1b").pattern.count("swa") == 5
    assert get_config("rwkv6-3b").pattern == ("rwkv",)
    assert get_config("qwen2-1.5b").qkv_bias


def test_param_counts_in_range():
    """Total parameter counts should be near the advertised sizes."""
    from repro.roofline import params_count
    approx = {
        "granite-8b": (7e9, 10e9),
        "deepseek-7b": (6e9, 8e9),
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "jamba-1.5-large-398b": (330e9, 420e9),
        "rwkv6-3b": (2.5e9, 4e9),
        "internvl2-26b": (18e9, 24e9),   # LM backbone only (frontend stub)
    }
    for arch, (lo, hi) in approx.items():
        n = params_count(get_config(arch))["total"]
        assert lo <= n <= hi, f"{arch}: {n:.3e}"


def test_moe_capacity_drops_are_bounded():
    cfg = tiny_cfg("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = batch_for(cfg, B=2, T=32)
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))


def test_long_context_flags():
    assert not get_config("granite-8b").is_subquadratic
    assert get_config("gemma3-1b").is_subquadratic
    assert get_config("rwkv6-3b").is_subquadratic
    assert get_config("jamba-1.5-large-398b").is_subquadratic
