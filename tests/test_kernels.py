"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs
pure-jnp oracle, plus hypothesis property tests (assignment (c))."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.kernels.flash_attention import ops as fops, ref as fref
from repro.kernels.gram import ops as gops, ref as gref
from repro.kernels.wkv6 import ops as wops, ref as wref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,s,h,hkv,dq,dv", [
    (1, 128, 128, 4, 4, 32, 32),      # MHA square
    (2, 128, 256, 4, 2, 64, 64),      # GQA, longer kv
    (1, 256, 256, 8, 1, 16, 32),      # MQA, dv != dq
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_vs_ref(b, t, s, h, hkv, dq, dv, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, t, h, dq), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dq), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dv), dtype)
    ref = fref.attention(q, k, v, causal=causal, window=window)
    pal = fops.attention(q, k, v, causal=causal, window=window,
                         impl="interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)
    xla = fops.attention(q, k, v, causal=causal, window=window, impl="xla",
                         bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(xla, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_xla_grad_matches_ref_grad():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 16))
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))

    def loss(f):
        def inner(q, k, v):
            return jnp.sum(jnp.square(f(q, k, v)))
        return jax.grad(inner, argnums=(0, 1, 2))(q, k, v)

    g_ref = loss(lambda q, k, v: fref.attention(q, k, v, causal=True))
    g_xla = loss(lambda q, k, v: fops.attention(q, k, v, causal=True,
                                                impl="xla", bq=32, bk=32))
    for a, b in zip(g_ref, g_xla):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([64, 128]), h=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 100))
def test_flash_softmax_rows_property(t, h, seed):
    """Attention output must lie in the convex hull of V rows: with V = const
    vector c, output == c exactly (softmax rows sum to 1)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(k1, (1, t, h, 16))
    k = jax.random.normal(k2, (1, t, h, 16))
    v = jnp.ones((1, t, h, 16)) * 3.5
    out = fops.attention(q, k, v, causal=True, impl="interpret")
    np.testing.assert_allclose(np.asarray(out), 3.5, rtol=1e-4)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,f", [(128, 128), (512, 256), (1024, 128)])
def test_gram_vs_ref(n, f, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, f), dtype)
    a = gops.gram(x, impl="interpret")
    b = gref.gram(x)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(a["s2"]), np.asarray(b["s2"]),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(a["s1"]), np.asarray(b["s1"]),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,f,bf,bn", [
    (300, 100, 32, 128),      # neither dim divisible -> both axes padded
    (100, 300, 128, 64),      # token dim padded, feature dim padded
    (257, 129, 128, 512),     # bn clamps to N, F one over the block
    (500, 64, 32, 256),       # only token-dim padding
])
def test_gram_padding_non_divisible(n, f, bf, bn):
    """Zero-padding lifts the old F%bf==0 / N%bn==0 assertion: arbitrary
    DeiT/LM shapes must match the reference exactly (zero rows/cols are
    invisible to both linear reductions)."""
    x = jax.random.normal(jax.random.PRNGKey(42), (n, f))
    a = gops.gram(x, impl="interpret", bf=bf, bn=bn)
    b = gref.gram(x)
    assert a["s2"].shape == (f, f) and a["s1"].shape == (f,)
    np.testing.assert_allclose(np.asarray(a["s2"]), np.asarray(b["s2"]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(a["s1"]), np.asarray(b["s1"]),
                               rtol=1e-4, atol=1e-4)


def test_gram_ops_default_dispatch_cpu():
    """On CPU the resolver picks the jnp reference (Pallas stays off the
    production path) and odd shapes go through without assertion."""
    x = jax.random.normal(jax.random.PRNGKey(3), (37, 23))
    out = gops.gram(x)
    ref = gref.gram(x)
    np.testing.assert_allclose(np.asarray(out["s2"]), np.asarray(ref["s2"]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,fx,fy,bf,bn", [
    (256, 128, 64, 128, 128),     # divisible shard tile
    (300, 100, 48, 32, 128),      # all dims padded, local tile only
    (128, 96, 96, 128, 512),      # square cross == gram
])
def test_gram_cross_vs_ref(n, fx, fy, bf, bn):
    """Rectangular X^T Y slab (the per-shard gram) matches the reference —
    zero-padding applies to each input's local shape independently."""
    kx, ky = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (n, fx))
    y = jax.random.normal(ky, (n, fy))
    a = gops.gram_cross(x, y, impl="interpret", bf=bf, bn=bn)
    b = gref.gram_cross(x, y)
    assert a["s2"].shape == (fx, fy) and a["s1"].shape == (fy,)
    np.testing.assert_allclose(np.asarray(a["s2"]), np.asarray(b["s2"]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(a["s1"]), np.asarray(b["s1"]),
                               rtol=1e-4, atol=1e-4)


def test_gram_cross_column_blocks_tile_full_gram():
    """Concatenating every shard's gram_cross slab over the column axis must
    reproduce gram(x) exactly — the invariant the model-sharded calibration
    layout rests on (docs/calibration.md)."""
    x = jax.random.normal(jax.random.PRNGKey(11), (192, 64))
    full = gref.gram(x)
    m = 4
    fl = x.shape[1] // m
    slabs = [gops.gram_cross(x, x[:, j * fl:(j + 1) * fl], impl="ref")
             for j in range(m)]
    s2 = np.concatenate([np.asarray(s["s2"]) for s in slabs], axis=1)
    s1 = np.concatenate([np.asarray(s["s1"]) for s in slabs])
    np.testing.assert_allclose(s2, np.asarray(full["s2"]), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(s1, np.asarray(full["s1"]), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_gram_psd_property(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256, 128))
    s2 = gops.gram(x, impl="interpret")["s2"]
    evs = np.linalg.eigvalsh(np.asarray(s2))
    assert evs.min() > -1e-3
    # symmetry
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s2).T, atol=1e-4)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,h,n,chunk", [(64, 2, 16, 16), (128, 1, 32, 32),
                                         (256, 4, 8, 64)])
def test_wkv6_vs_ref(t, h, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B = 2
    r = jax.random.normal(ks[0], (B, t, h, n))
    k = jax.random.normal(ks[1], (B, t, h, n))
    v = jax.random.normal(ks[2], (B, t, h, n))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, t, h, n))) * 0.6 + 0.35
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    y_ref, s_ref = wref.wkv6(r, k, v, w, u)
    y_pal, s_pal = wops.wkv6(r, k, v, w, u, impl="interpret", chunk=chunk)
    y_xla, s_xla = wops.wkv6(r, k, v, w, u, impl="xla", chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)


def test_wkv6_state_continuation():
    """Running two halves with carried state == one full pass."""
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    B, T, H, N = 1, 64, 2, 16
    r = jax.random.normal(ks[0], (B, T, H, N))
    k = jax.random.normal(ks[1], (B, T, H, N))
    v = jax.random.normal(ks[2], (B, T, H, N))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    y_full, s_full = wref.wkv6(r, k, v, w, u)
    y1, s1 = wref.wkv6(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u)
    y2, s2 = wref.wkv6(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u,
                       state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500))
def test_wkv6_decay_zero_kills_history(seed):
    """w == tiny -> state holds only the previous step's kv outer product
    (decay applies to S BEFORE the new kv is added), so
    y_t = (r_t . k_{t-1}) v_{t-1} + (r_t . (u*k_t)) v_t."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, T, H, N = 1, 16, 1, 8
    r = jax.random.normal(ks[0], (B, T, H, N))
    k = jax.random.normal(ks[1], (B, T, H, N))
    v = jax.random.normal(ks[2], (B, T, H, N))
    u = jax.random.normal(ks[3], (H, N)) * 0.1
    w = jnp.full((B, T, H, N), 1e-30)
    y, _ = wref.wkv6(r, k, v, w, u)
    bonus = jnp.einsum("bthn,hn,bthn->bth", r, u, k)[..., None] * v
    kprev = jnp.concatenate([jnp.zeros_like(k[:, :1]), k[:, :-1]], 1)
    vprev = jnp.concatenate([jnp.zeros_like(v[:, :1]), v[:, :-1]], 1)
    hist = jnp.einsum("bthn,bthn->bth", r, kprev)[..., None] * vprev
    np.testing.assert_allclose(np.asarray(y), np.asarray(bonus + hist),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# flash_decode (split-KV decode attention)
# ---------------------------------------------------------------------------

from repro.kernels.flash_decode import ops as dops, ref as dref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hkv,dq,dv,bs", [
    (2, 256, 4, 4, 32, 32, 64),       # MHA
    (1, 512, 8, 2, 64, 64, 128),      # GQA
    (2, 256, 4, 1, 16, 32, 64),       # MQA, dv != dq
])
def test_flash_decode_vs_ref(b, s, h, hkv, dq, dv, bs, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, dq), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dq), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dv), dtype)
    # ragged validity (different live lengths per row, like a real cache)
    lens = jnp.asarray([s // 2, s][:b] + [s] * max(0, b - 2))
    valid = jnp.arange(s)[None, :] < lens[:, None]
    ref = dref.decode_attention(q, k, v, valid, scale=0.125)
    pal = dops.decode_attention(q, k, v, valid, scale=0.125, bs=bs,
                                impl="interpret")
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 300), nsplit=st.sampled_from([2, 4, 8]))
def test_flash_decode_split_invariance(seed, nsplit):
    """The logsumexp merge must make the result independent of the split
    count (the FlashDecoding correctness property)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, s, h, d = 1, 128, 2, 16
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    valid = jnp.ones((b, s), bool)
    outs = [dops.decode_attention(q, k, v, valid, bs=s // n,
                                  impl="interpret")
            for n in (1, nsplit)]
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=1e-5, atol=1e-5)


def test_decode_path_uses_kernel_consistently():
    """Model decode with REPRO_DECODE_IMPL=interpret must match the jnp
    path bit-for-bit-ish (kernel wired into attention._decode_sdpa)."""
    import os
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("granite-8b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    lg, cache = model.prefill(params, {"tokens": toks}, 16)
    step = toks[:, -1:]
    l0, _ = model.decode_step(params, step, cache)
    os.environ["REPRO_DECODE_IMPL"] = "interpret"
    try:
        l1, _ = model.decode_step(params, step, cache)
    finally:
        del os.environ["REPRO_DECODE_IMPL"]
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-4,
                               atol=1e-4)
