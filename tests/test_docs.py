"""Documentation health: every local markdown link resolves, the docs the
README promises exist, and the CLI reference covers every prune flag.

Cheap (no jax import in the subprocess): keeps docs inside the tier-1 gate
so a file move that orphans README/docs links fails the suite, not just the
CI docs job.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_markdown_links_resolve():
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_docs.py"), ROOT],
        capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, f"broken docs links:\n{r.stdout}{r.stderr}"


def test_readme_and_docs_exist():
    for rel in ("README.md", "docs/calibration.md", "docs/cli.md",
                "docs/kernels.md", "docs/roofline.md", "docs/pipeline.md",
                "docs/serving.md", "ROADMAP.md", "PAPER.md"):
        assert os.path.exists(os.path.join(ROOT, rel)), rel


def test_readme_links_serving_doc():
    """The serving-engine design doc must stay reachable from the README
    (acceptance criterion of the continuous-batching PR)."""
    readme = open(os.path.join(ROOT, "README.md"), encoding="utf-8").read()
    assert "docs/serving.md" in readme


def test_readme_links_pipeline_doc():
    """The one-traversal design doc must stay reachable from the README
    (acceptance criterion of the speculative-calibration PR)."""
    readme = open(os.path.join(ROOT, "README.md"), encoding="utf-8").read()
    assert "docs/pipeline.md" in readme


def test_pipeline_doc_carries_hit_rate_table():
    """docs/pipeline.md must contain the margin-vs-hit-rate experiment
    table (the columns bench_calibration.py --one-traversal emits) and
    name the bench that regenerates it, so the numbers stay auditable."""
    doc = open(os.path.join(ROOT, "docs", "pipeline.md"),
               encoding="utf-8").read()
    assert "| arch | margin | candidates/keep | hit-rate |" in doc
    assert "--one-traversal" in doc
    assert "bench_calibration.py" in doc


def _cli_flags(module):
    src = open(os.path.join(ROOT, "src", "repro", "launch",
                            f"{module}.py"), encoding="utf-8").read()
    flags = set(re.findall(r'add_argument\("(--[a-z0-9-]+)"', src))
    assert flags, f"no flags parsed from launch/{module}.py"
    return flags


def _prune_flags():
    return _cli_flags("prune")


def _serve_flags():
    return _cli_flags("serve")


def test_cli_doc_covers_every_prune_flag():
    """docs/cli.md must document every --flag launch/prune.py defines (so a
    new flag without docs fails here, not in review)."""
    flags = _prune_flags()
    doc = open(os.path.join(ROOT, "docs", "cli.md"), encoding="utf-8").read()
    missing = {f for f in flags if f"`{f}`" not in doc}
    assert not missing, f"flags undocumented in docs/cli.md: {sorted(missing)}"


def test_cli_doc_covers_every_serve_flag():
    """Same coverage direction for the serving CLI: every --flag
    launch/serve.py defines (--trace, --slots, ...) must be documented."""
    flags = _serve_flags()
    doc = open(os.path.join(ROOT, "docs", "cli.md"), encoding="utf-8").read()
    missing = {f for f in flags if f"`{f}`" not in doc}
    assert not missing, f"flags undocumented in docs/cli.md: {sorted(missing)}"


def _table_flags(rel):
    """`--flag` tokens in the first column of ``rel``'s markdown tables."""
    documented = set()
    for line in open(os.path.join(ROOT, rel), encoding="utf-8"):
        if line.startswith("|"):
            documented |= set(re.findall(r"`(--[a-z0-9-]+)`",
                                         line.split("|")[1]))
    return documented


def test_cli_doc_has_no_stale_flags():
    """The reverse direction: every `--flag` docs/cli.md's Flags tables
    document must still exist in launch/prune.py or launch/serve.py —
    catches renamed or removed flags leaving stale docs behind (the
    --rank-policy drift class fixed in PR 2)."""
    flags = _prune_flags() | _serve_flags()
    documented = _table_flags("docs/cli.md")
    assert documented, "no flags parsed from docs/cli.md's table"
    stale = documented - flags
    assert not stale, f"docs/cli.md documents removed flags: {sorted(stale)}"


def test_pipeline_doc_has_no_stale_prune_flags():
    """Same stale-flag reverse check for docs/pipeline.md: any launch flag
    its tables lead with must still exist in launch/prune.py, so the
    one-traversal narrative can't drift from the CLI it describes."""
    stale = _table_flags("docs/pipeline.md") - _prune_flags()
    assert not stale, \
        f"docs/pipeline.md documents removed flags: {sorted(stale)}"


def test_one_traversal_flags_documented():
    """The speculative-calibration flags must exist in the CLI and be
    documented (belt-and-braces on top of the generic coverage check)."""
    flags = _prune_flags()
    assert {"--one-traversal", "--spec-margin"} <= flags
    doc = open(os.path.join(ROOT, "docs", "cli.md"), encoding="utf-8").read()
    for f in ("--one-traversal", "--spec-margin"):
        assert f"`{f}`" in doc, f


def test_frontend_flags_documented():
    """The serving front-end flags must exist in the CLI and be documented
    in cli.md AND covered by serving.md's Front-end section (belt-and-
    braces on top of the generic coverage check)."""
    flags = _serve_flags()
    frontend = {"--queue-depth", "--deadline-ms", "--deadline-frac",
                "--prefix-cache", "--prefix-len", "--spf"}
    assert frontend <= flags, sorted(frontend - flags)
    cli = open(os.path.join(ROOT, "docs", "cli.md"), encoding="utf-8").read()
    for f in sorted(frontend):
        assert f"`{f}`" in cli, f
    serving = open(os.path.join(ROOT, "docs", "serving.md"),
                   encoding="utf-8").read()
    assert "## Front-end" in serving
    for needle in ("Overloaded", "queue-depth", "prefix cache", "deadline"):
        assert needle in serving, needle


def test_zoo_serving_flags_documented():
    """The config-zoo serving flags must exist in their CLIs and be
    documented in cli.md, and serving.md must carry the slot-cache
    contracts section the zoo matrix and engine dispatch rely on
    (belt-and-braces on top of the generic two-direction coverage)."""
    assert "--expert-sparsity" in _prune_flags()
    assert {"--expert-sparsity", "--mem-len"} <= _serve_flags()
    cli = open(os.path.join(ROOT, "docs", "cli.md"), encoding="utf-8").read()
    for f in ("--expert-sparsity", "--mem-len"):
        assert f"`{f}`" in cli, f
    serving = open(os.path.join(ROOT, "docs", "serving.md"),
                   encoding="utf-8").read()
    assert "## Slot-cache contracts" in serving
    for needle in ("recurrent", "mem_len", "expert", "cache_contract",
                   "errors.py"):
        assert needle in serving, needle


def test_sharded_serving_flags_documented():
    """The mesh-sharded serving flags must exist in the CLI and be
    documented in cli.md, and serving.md must carry the Mesh-sharded
    serving section with the leaf placement table, the scatter-admit
    soundness argument, and the footprint math the gates rely on
    (belt-and-braces on top of the generic two-direction coverage)."""
    assert {"--mesh-shape", "--serve-sharded"} <= _serve_flags()
    cli = open(os.path.join(ROOT, "docs", "cli.md"), encoding="utf-8").read()
    for f in ("--mesh-shape", "--serve-sharded"):
        assert f"`{f}`" in cli, f
    serving = open(os.path.join(ROOT, "docs", "serving.md"),
                   encoding="utf-8").read()
    assert "## Mesh-sharded serving" in serving
    for needle in ("slot_specs", "shard_ineligible", "scatter",
                   "device_bytes_estimate", "replicated", "eff_qk",
                   "bench_serve_sharded.py", "all-or-nothing"):
        assert needle in serving, needle


def test_scheduler_flags_documented():
    """The scheduler's chunked-prefill flag must exist in the CLI and be
    documented in cli.md, and serving.md must carry the Scheduler section
    with the layer diagram, the chunk-interleaving exactness argument,
    and the per-contract eligibility table (belt-and-braces on top of
    the generic two-direction coverage)."""
    assert "--prefill-chunk" in _serve_flags()
    cli = open(os.path.join(ROOT, "docs", "cli.md"), encoding="utf-8").read()
    assert "`--prefill-chunk`" in cli
    serving = open(os.path.join(ROOT, "docs", "serving.md"),
                   encoding="utf-8").read()
    assert "## Scheduler" in serving
    for needle in ("begin_admit", "continue_admit", "PREFILLING",
                   "byte-identical", "chunk-eligible", "chunk_invalid",
                   "chunk_unsupported", "write_slot", "scheduler_trace.md"):
        assert needle in serving, needle


def test_readme_documents_subprocess_marker():
    """README must explain deselecting the environment-sensitive
    subprocess tests (`-m "not subprocess"`)."""
    readme = open(os.path.join(ROOT, "README.md"), encoding="utf-8").read()
    assert "not subprocess" in readme
