"""Documentation health: every local markdown link resolves, the docs the
README promises exist, and the CLI reference covers every prune flag.

Cheap (no jax import in the subprocess): keeps docs inside the tier-1 gate
so a file move that orphans README/docs links fails the suite, not just the
CI docs job.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_markdown_links_resolve():
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_docs.py"), ROOT],
        capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, f"broken docs links:\n{r.stdout}{r.stderr}"


def test_readme_and_docs_exist():
    for rel in ("README.md", "docs/calibration.md", "docs/cli.md",
                "docs/kernels.md", "docs/roofline.md",
                "ROADMAP.md", "PAPER.md"):
        assert os.path.exists(os.path.join(ROOT, rel)), rel


def _prune_flags():
    src = open(os.path.join(ROOT, "src", "repro", "launch",
                            "prune.py"), encoding="utf-8").read()
    flags = set(re.findall(r'add_argument\("(--[a-z0-9-]+)"', src))
    assert flags, "no flags parsed from launch/prune.py"
    return flags


def test_cli_doc_covers_every_prune_flag():
    """docs/cli.md must document every --flag launch/prune.py defines (so a
    new flag without docs fails here, not in review)."""
    flags = _prune_flags()
    doc = open(os.path.join(ROOT, "docs", "cli.md"), encoding="utf-8").read()
    missing = {f for f in flags if f"`{f}`" not in doc}
    assert not missing, f"flags undocumented in docs/cli.md: {sorted(missing)}"


def test_cli_doc_has_no_stale_prune_flags():
    """The reverse direction: every `--flag` docs/cli.md's Flags table
    documents must still exist in launch/prune.py — catches renamed or
    removed flags leaving stale docs behind (the --rank-policy drift class
    fixed in PR 2)."""
    flags = _prune_flags()
    doc = open(os.path.join(ROOT, "docs", "cli.md"), encoding="utf-8")
    documented = set()
    for line in doc:
        if line.startswith("|"):
            documented |= set(re.findall(r"`(--[a-z0-9-]+)`",
                                         line.split("|")[1]))
    assert documented, "no flags parsed from docs/cli.md's table"
    stale = documented - flags
    assert not stale, f"docs/cli.md documents removed flags: {sorted(stale)}"
