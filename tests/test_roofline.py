"""Roofline analysis machinery: HLO collective parsing, jaxpr FLOP counter,
model-flops accounting."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.roofline.analysis import (collective_bytes, jaxpr_matmul_flops,
                                     model_flops, params_count)


def test_collective_parse():
    hlo = """
  %ag = bf16[128,1024]{1,0} all-gather(%x), replica_groups=...
  %ar.5 = f32[64]{0} all-reduce(%y), to_apply=%sum
  %rs = (f32[32,32]{1,0}, f32[8]{0}) reduce-scatter(%a, %b)
  %cp = u8[16]{0} collective-permute(%z)
  %notacoll = f32[2,2]{1,0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 128 * 1024 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["reduce-scatter"] == 32 * 32 * 4 + 8 * 4
    assert out["collective-permute"] == 16
    assert out["all-to-all"] == 0


def test_collective_parse_unknown_dtype_floor(caplog):
    """Dtypes missing from _DTYPE_BYTES (f8e4m3 etc.) must be counted with
    a 1-byte-per-element floor and a warning — silently dropping them
    undercounted collective traffic for fp8-quantised modules."""
    import logging
    from repro.roofline import analysis
    analysis._WARNED_DTYPES.clear()
    hlo = """
  %ag = f8e4m3[256,128]{1,0} all-gather(%x), replica_groups=...
  %ar = f32[64]{0} all-reduce(%y), to_apply=%sum
"""
    with caplog.at_level(logging.WARNING, logger="repro.roofline"):
        out = collective_bytes(hlo)
    assert out["all-gather"] == 256 * 128 * 1      # 1-byte floor
    assert out["all-reduce"] == 64 * 4             # known dtypes unchanged
    assert any("f8e4m3" in r.message for r in caplog.records)
    # warned once per dtype, not once per shape
    analysis._shape_bytes("f8e4m3[4]")
    assert sum("f8e4m3" in r.message for r in caplog.records) == 1


def test_jaxpr_flops_dense():
    a = jnp.zeros((64, 128))
    b = jnp.zeros((128, 32))
    f = jaxpr_matmul_flops(lambda x, y: x @ y, a, b)
    assert f == 2 * 64 * 128 * 32


def test_jaxpr_flops_scan_multiplies_trip_count():
    x = jnp.zeros((32, 32))

    def body(c, _):
        return c @ c, None

    def fn(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    assert jaxpr_matmul_flops(fn, x) == 7 * 2 * 32 ** 3


def test_jaxpr_flops_through_grad_and_remat():
    w = jnp.zeros((16, 16))

    def loss(w):
        h = jax.checkpoint(lambda a: a @ a)(w)
        return jnp.sum(h)

    fwd = jaxpr_matmul_flops(lambda w: w @ w, w)
    both = jaxpr_matmul_flops(jax.grad(loss), w)
    # grad of matmul = 2 matmuls (+ remat recompute of the fwd)
    assert both >= 2 * fwd


def test_params_count_moe_active_fraction():
    cfg = get_config("qwen3-moe-235b-a22b")
    pc = params_count(cfg)
    # a22b: ~22B active of ~235B total
    assert 15e9 < pc["active"] < 30e9
    assert pc["active"] < pc["total"] / 5


@pytest.mark.parametrize("shape_name,mult", [("train_4k", 6.0),
                                             ("prefill_32k", 2.0)])
def test_model_flops_scaling(shape_name, mult):
    cfg = get_config("qwen2-1.5b")
    shape = SHAPES[shape_name]
    mf = model_flops(cfg, shape)
    pc = params_count(cfg)
    toks = shape.global_batch * shape.seq_len
    assert mf == pytest.approx(mult * pc["active"] * toks)


@pytest.mark.subprocess
def test_cache_partition_specs_finds_batch_dim():
    """Stacked caches carry a leading reps dim — the batch dim must still be
    found and sharded (the §Perf G1 regression guard)."""
    import subprocess
    import sys
    import os
    ROOT = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    # force CPU: without it jax probes the TPU backend, and on TPU-shaped
    # containers without TPU metadata libtpu retries for ~7 minutes —
    # blowing this subprocess's 120 s timeout (host devices are CPU-only)
    env["JAX_PLATFORMS"] = "cpu"
    code = """
import jax, jax.numpy as jnp
from repro.launch.dryrun import cache_partition_specs
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4))
sds = {'k': jax.ShapeDtypeStruct((36, 8, 1024, 4, 64), jnp.bfloat16),
       'pos': jax.ShapeDtypeStruct((8,), jnp.int32)}
spec = cache_partition_specs(sds, mesh, global_batch=8)
assert spec['k'][1] == 'data', spec['k']
assert 'model' in [a for a in spec['k'] if a], spec['k']
print('OK')
"""
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
