import os
import sys

# Smoke tests and benches see the single real CPU device; only the dry-run
# entry point forces 512 host devices (per assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
