import os
import sys

# Smoke tests and benches see the single real CPU device; only the dry-run
# entry point forces 512 host devices (per assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # tests that shell out to a fresh python (multi-device dry runs):
    # historically environment-sensitive (backend probing, device-count
    # env vars) — deselect with `-m "not subprocess"` on minimal hosts
    config.addinivalue_line(
        "markers",
        "subprocess: spawns a fresh python with its own jax backend "
        "(deselect with -m 'not subprocess')")
