"""Continuous-batching serving engine tests (docs/serving.md).

Covers: prefill+decode == full-sequence-forward parity (LM and enc-dec),
zero-sparsity pruned serving token-identity through the engine, slot
admit/retire/refill correctness on a ragged trace, pruned cache shrinkage,
ragged-prefill soundness, and the serve_loop token off-by-one regression.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import ServeEngine, synthetic_trace
from repro.serve.engine import Request

from helpers import calib_factory, greedy_chain_ok as _greedy_chain_ok, \
    tiny_cfg


def _lm_cfg():
    return reduced(get_config("qwen2-1.5b")).replace(dtype="float32")


@pytest.fixture(scope="module")
def trained_lm():
    """Briefly trained tiny LM: training sharpens the logits so greedy
    argmax is far from ties and token-equality checks are robust."""
    from repro.launch.train import train
    cfg = _lm_cfg()
    params, _, _ = train(cfg, steps=25, batch=8, seq=32, ckpt_dir=None,
                         peak_lr=2e-3, log=lambda *a: None)
    return cfg, build_model(cfg), params


# ---------------------------------------------------------------------------
# engine parity vs full-sequence forward
# ---------------------------------------------------------------------------

def test_engine_lm_parity_full_forward(trained_lm):
    cfg, model, params = trained_lm
    trace = synthetic_trace(5, cfg.vocab_size, seed=3,
                            prompt_range=(4, 20), gen_range=(2, 8))
    eng = ServeEngine(model, params, n_slots=2, max_len=48)
    comps = eng.run(trace)
    assert eng.ragged_ok           # bucketed ragged prefill exercised
    for req, c in zip(trace, comps):
        assert len(c.tokens) == req.gen
        assert _greedy_chain_ok(model, params, req, c.tokens), req.rid


def test_engine_encdec_parity_full_forward():
    cfg = tiny_cfg("seamless-m4t-large-v2")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    mem = 10
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size,
                                       size=p).astype(np.int32),
                    gen=g,
                    frames=rng.randn(mem, cfg.d_model).astype(np.float32))
            for i, (p, g) in enumerate([(5, 4), (9, 6), (3, 2)])]
    eng = ServeEngine(model, params, n_slots=2, max_len=24, mem_len=mem)
    comps = eng.run(reqs)
    for req, c in zip(reqs, comps):
        assert len(c.tokens) == req.gen
        assert _greedy_chain_ok(model, params, req, c.tokens), req.rid


def test_engine_exact_length_fallback_swa():
    """Sliding-window archs are not ragged-eligible: the engine must fall
    back to exact-length prefill and still match the full forward."""
    cfg = tiny_cfg("gemma3-1b")
    assert "swa" in cfg.layer_kinds
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    reqs = [Request(rid=i, tokens=rng.randint(0, cfg.vocab_size,
                                              size=p).astype(np.int32), gen=g)
            for i, (p, g) in enumerate([(6, 3), (6, 4), (9, 3)])]
    eng = ServeEngine(model, params, n_slots=2, max_len=24)
    assert not eng.ragged_ok
    comps = eng.run(reqs)
    for req, c in zip(reqs, comps):
        assert _greedy_chain_ok(model, params, req, c.tokens), req.rid


# ---------------------------------------------------------------------------
# ragged (bucketed) prefill soundness
# ---------------------------------------------------------------------------

def test_ragged_prefill_matches_exact_prefill():
    """Right-padded prefill with lengths= must produce the same logits and
    an equivalent cache to the exact-length prefill."""
    cfg = _lm_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P, L, max_len = 11, 16, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, P), 0,
                              cfg.vocab_size)
    padded = jnp.pad(toks, ((0, 0), (0, L - P)))
    lg_exact, cache_exact = model.prefill(params, {"tokens": toks}, max_len)
    lg_ragged, cache_ragged = model.prefill(
        params, {"tokens": padded}, max_len,
        lengths=jnp.full((2,), P, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_exact), np.asarray(lg_ragged),
                               rtol=1e-5, atol=1e-5)
    # decode one step from both caches: identical logits
    nxt = jnp.argmax(lg_exact[:, -1, : cfg.vocab_size],
                     -1)[:, None].astype(jnp.int32)
    d1, _ = model.decode_step(params, nxt, cache_exact)
    d2, _ = model.decode_step(params, nxt, cache_ragged)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-5)


def test_ragged_prefill_rejected_on_swa():
    cfg = tiny_cfg("gemma3-1b")
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="ragged prefill"):
        jax.eval_shape(
            lambda p: model.prefill(
                p, {"tokens": jnp.zeros((1, 8), jnp.int32)}, 16,
                lengths=jnp.full((1,), 4, jnp.int32)), params)


# ---------------------------------------------------------------------------
# slot lifecycle on a ragged trace
# ---------------------------------------------------------------------------

def test_slot_admit_retire_refill(trained_lm):
    cfg, model, params = trained_lm
    trace = synthetic_trace(7, cfg.vocab_size, seed=5,
                            prompt_range=(4, 12), gen_range=(2, 10))
    eng = ServeEngine(model, params, n_slots=2, max_len=32)
    comps = eng.run(trace)
    assert [c.rid for c in comps] == [r.rid for r in trace]
    assert all(len(c.tokens) == r.gen for c, r in zip(comps, trace))
    # with 7 requests over 2 slots, slots MUST have been refilled mid-flight
    assert eng.stats["admits"] == 7
    assert eng.stats["refills"] >= 5
    assert eng.stats["max_concurrent"] == 2
    assert all(s.free for s in eng.slots)
    # refills must not contaminate neighbours: every stream still matches
    # its own full-sequence greedy chain
    for req, c in zip(trace, comps):
        assert _greedy_chain_ok(model, params, req, c.tokens), req.rid


def test_gen_one_request_completes_at_admit(trained_lm):
    cfg, model, params = trained_lm
    reqs = [Request(rid=0, tokens=np.arange(5, dtype=np.int32), gen=1),
            Request(rid=1, tokens=np.arange(7, dtype=np.int32), gen=3)]
    eng = ServeEngine(model, params, n_slots=1, max_len=16)
    comps = eng.run(reqs)
    assert len(comps[0].tokens) == 1 and len(comps[1].tokens) == 3
    for req, c in zip(reqs, comps):
        assert _greedy_chain_ok(model, params, req, c.tokens)


# ---------------------------------------------------------------------------
# pruned serving
# ---------------------------------------------------------------------------

def test_zero_sparsity_pruned_token_identical(trained_lm):
    """CORP at zero sparsity is the identity; the engine must serve the
    'pruned' model token-identically to the dense one."""
    from repro.core import PruneConfig, corp_prune
    cfg, model, params = trained_lm
    pruned, pcfg, _ = corp_prune(model, params, calib_factory(cfg),
                                 PruneConfig(0.0, 0.0))
    trace = synthetic_trace(4, cfg.vocab_size, seed=7,
                            prompt_range=(4, 16), gen_range=(3, 6))
    dense = ServeEngine(model, params, n_slots=2, max_len=32).run(trace)
    served = ServeEngine(build_model(pcfg), pruned,
                         n_slots=2, max_len=32).run(trace)
    for a, b in zip(dense, served):
        assert list(a.tokens) == list(b.tokens)


def test_pruned_config_shrinks_cache():
    """Pruned qk dims shrink the preallocated KV cache — the structured-
    pruning serving payoff the engine exists to exploit."""
    cfg = _lm_cfg()
    pcfg = cfg.pruned(0.5, 0.5)
    assert pcfg.eff_qk < cfg.d_head
    dense = ServeEngine(build_model(cfg),
                        build_model(cfg).init(jax.random.PRNGKey(0)),
                        n_slots=4, max_len=64)
    pruned = ServeEngine(build_model(pcfg),
                         build_model(pcfg).init(jax.random.PRNGKey(0)),
                         n_slots=4, max_len=64)
    assert pruned.cache_bytes < dense.cache_bytes
    # K rows carry the pruned per-head dim
    k_dims = {leaf.shape[-1] for path, leaf in
              jax.tree_util.tree_flatten_with_path(pruned.slotcache.cache)[0]
              if any(getattr(p, "key", None) == "k" for p in path)}
    assert k_dims == {pcfg.eff_qk}


# ---------------------------------------------------------------------------
# serve_loop regression (token off-by-one)
# ---------------------------------------------------------------------------

def test_serve_loop_returns_exactly_gen_matching_tokens(trained_lm):
    """serve_loop must return exactly ``gen`` tokens and every one of them
    must match the full-sequence model.apply argmax rollout — the old loop
    ran one extra decode step and discarded its token, shifting the stream
    off the timed region."""
    from repro.launch.serve import serve_loop
    cfg, model, params = trained_lm
    batch, prompt_len, gen, seed = 2, 12, 6, 0
    out, t_prefill, t_decode = serve_loop(
        model, params, batch=batch, prompt_len=prompt_len, gen=gen,
        max_len=prompt_len + gen + 1, seed=seed, log=lambda *a: None)
    assert out.shape == (batch, gen)
    # reconstruct serve_loop's prompt and greedy-roll the full forward
    rng = np.random.RandomState(seed)
    toks = rng.randint(0, cfg.vocab_size,
                       size=(batch, prompt_len)).astype(np.int32)
    seq = jnp.asarray(toks)
    for t in range(gen):
        logits = model.apply(params, {"tokens": seq})[0]
        nxt = jnp.argmax(logits[:, -1, : cfg.vocab_size],
                         -1)[:, None].astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(out[:, t]),
                                      np.asarray(nxt[:, 0]), f"step {t}")
        seq = jnp.concatenate([seq, nxt], axis=1)
    assert t_prefill > 0 and t_decode > 0


# ---------------------------------------------------------------------------
# chunked prefill (serve/scheduler.py policy over the non-atomic admit)
# ---------------------------------------------------------------------------

def test_chunked_prefill_token_identical(trained_lm):
    """run(prefill_chunk=c) must stream byte-identically to the unchunked
    engine on the same trace, for several chunk sizes."""
    cfg, model, params = trained_lm
    trace = synthetic_trace(5, cfg.vocab_size, seed=11,
                            prompt_range=(4, 20), gen_range=(2, 8))
    base = ServeEngine(model, params, n_slots=2, max_len=48).run(trace)
    for c in (1, 3, 7):
        eng = ServeEngine(model, params, n_slots=2, max_len=48)
        comps = eng.run(trace, prefill_chunk=c)
        for b, ch in zip(base, comps):
            assert b.tokens.tolist() == ch.tokens.tolist(), (c, b.rid)
        assert eng.stats["admits"] == len(trace)
        assert all(s.free and s.pending is None for s in eng.slots)


def test_begin_continue_lifecycle(trained_lm):
    """The non-atomic admit surface: a PREFILLING slot is occupied but
    not decoding; continue_admit without begin_admit is refused from the
    errors table; installation is all-at-once."""
    from repro.serve import ERRORS
    cfg, model, params = trained_lm
    eng = ServeEngine(model, params, n_slots=2, max_len=32)
    eng.begin()
    import re
    with pytest.raises(ValueError,
                       match=re.escape(
                           ERRORS["continue_without_begin"].format(
                               slot=0))):
        eng.continue_admit(0)
    req = Request(rid=0, tokens=np.arange(1, 11, dtype=np.int32), gen=3)
    eng.begin_admit(req, 0)
    assert eng.active_count() == 1 and eng.decoding_count() == 0
    assert not eng.slots[0].free and eng.slots[0].out == []
    steps = 0
    while not eng.continue_admit(0, 3):
        steps += 1
        assert steps < 10
    assert steps > 0 and eng.stats["chunk_steps"] == steps
    assert eng.decoding_count() == 1
    assert len(eng.slots[0].out) == 1       # exactly the prefill token
    base = ServeEngine(model, params, n_slots=1, max_len=32).run([req])
    assert eng.slots[0].out[0] == int(base[0].tokens[0])
    eng.cancel(0)
    assert eng.active_count() == 0


def test_cancel_mid_chunked_prefill_keeps_zero_tokens(trained_lm):
    """Cancelling a PREFILLING slot discards the partial prefill: zero
    tokens kept, the slot is immediately refillable."""
    cfg, model, params = trained_lm
    eng = ServeEngine(model, params, n_slots=1, max_len=32)
    eng.begin()
    eng.begin_admit(Request(rid=0, tokens=np.arange(1, 11, dtype=np.int32),
                            gen=4), 0)
    assert not eng.continue_admit(0, 2)     # mid-prefill
    assert eng.cancel(0) == []
    assert eng.slots[0].free and eng.slots[0].pending is None
    # refill over the same slot still serves exactly
    req = Request(rid=1, tokens=np.arange(2, 8, dtype=np.int32), gen=3)
    comps = eng.run([req])
    assert _greedy_chain_ok(model, params, req, comps[0].tokens)


def test_prefill_stats_keys_are_bounded():
    """Regression: the exact-length fallback used to key prefill stats by
    raw prompt length — one counter per distinct length, an unbounded
    cardinality. Keys must now come from the finite bucket set, on both
    the exact-length fallback (swa) and the ragged path."""
    cfg = tiny_cfg("gemma3-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    reqs = [Request(rid=i, tokens=rng.randint(0, cfg.vocab_size,
                                              size=p).astype(np.int32),
                    gen=2)
            for i, p in enumerate((3, 5, 6, 7, 9))]   # 5 distinct lengths
    eng = ServeEngine(model, params, n_slots=2, max_len=24)
    assert not eng.ragged_ok                # the exact-length fallback
    eng.run(reqs)
    allowed = {f"prefill_b{b}" for b in eng.buckets}
    seen = {k for k in eng.stats if k.startswith("prefill_b")}
    assert seen and seen <= allowed, (seen, allowed)
    # chunked serving on the same engine family stays bounded too
    eng2 = ServeEngine(model, params, n_slots=2, max_len=24)
    eng2.run(reqs, prefill_chunk=2)
    seen2 = {k for k in eng2.stats if k.startswith("prefill_b")}
    assert seen2 and seen2 <= allowed, (seen2, allowed)
