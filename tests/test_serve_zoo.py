"""Config-zoo serving smoke: every LM config in ``repro.configs`` must
admit one request and take two decode steps through the slot engine, and
serve token-identical to its own full-sequence greedy forward.

The zoo spans pure-attn, sliding-window, recurrent (rwkv), hybrid
(jamba), MoE and enc-dec stacks; serving regressions historically hid in
the configs the serve tests didn't cover. The *ragged/prefix* features
are sound exactly where the slot-cache contract is replayable (pure
global-attention KV rewind, or whole-prefix recurrent state snapshots —
docs/serving.md "slot-cache contracts"); the remaining gaps are
``xfail(strict=True)`` entries whose reasons are BUILT from the shared
``repro.serve.errors`` table, so the engine's refusal text and this
matrix cannot drift apart — a silently widening (or narrowing) feature
surface flips a test and forces this file to be updated deliberately.
"""
from __future__ import annotations

import re

import jax
import numpy as np
import pytest

from helpers import greedy_chain_ok, tiny_cfg
from repro.configs import ARCH_IDS, DEIT_IDS
from repro.serve import (PrefixCache, RecurrentSlotCache, ReplicaRouter,
                         ServeEngine, ServeFrontend, Status, cache_contract,
                         slot_specs)
from repro.serve import errors
from repro.models import build_model
from repro.serve.engine import Request

MEM_LEN = 8        # enc-dec encoder-memory length used throughout

# configs with no replayable slot-cache contract (the swa ring buffer is
# neither a rewindable KV nor a whole-prefix recurrent snapshot); the
# xfail reason is the engine's own refusal, formatted from the shared
# error table — literal duplication is rejected by tests/test_serve_errors
PREFIX_GAPS = {
    "gemma3-1b": "prefix_ineligible",
}

# configs that cannot model-shard their slot cache on a 2-way model axis:
# the reduced GQA stacks collapse to a single kv head (and jamba's hybrid
# attn rows with them), so leaf 'k' has no dim divisible by the axis —
# sharding is all-or-nothing, never padded (docs/serving.md "Mesh-sharded
# serving"). The xfail reason is the engine's own refusal, formatted from
# the shared error table, so the refusal text and this matrix cannot
# drift apart.
SHARD_MESH_M = 2
SHARD_GAPS = frozenset({"granite-8b", "gemma3-1b", "qwen2-1.5b",
                        "internvl2-26b", "qwen3-moe-235b-a22b",
                        "jamba-1.5-large-398b"})


def _shard_params():
    return [pytest.param(a, marks=pytest.mark.xfail(
                reason=errors.msg("shard_ineligible",
                                  name=tiny_cfg(a).name, leaf="k",
                                  m=SHARD_MESH_M), strict=True))
            if a in SHARD_GAPS else
            pytest.param(a, marks=pytest.mark.subprocess)
            for a in ARCH_IDS]


def _gap_reason(arch: str, key: str) -> str:
    return errors.msg(key, name=tiny_cfg(arch).name)


def _gap_params(key_for_prefix: str):
    return [pytest.param(a, marks=pytest.mark.xfail(
        reason=_gap_reason(a, key_for_prefix), strict=True))
        if a in PREFIX_GAPS else a for a in ARCH_IDS]


@pytest.fixture(scope="module")
def zoo():
    """Lazy per-arch (model, params) cache shared across this module."""
    built = {}

    def get(arch):
        if arch not in built:
            cfg = tiny_cfg(arch)
            model = build_model(cfg)
            built[arch] = (model, model.init(jax.random.PRNGKey(0)))
        return built[arch]

    return get


def _engine(model, params, n_slots=1, max_len=32):
    kw = dict(n_slots=n_slots, max_len=max_len)
    if model.cfg.family == "encdec":
        kw["mem_len"] = MEM_LEN
    return ServeEngine(model, params, **kw)


def _req(cfg, rid=0, plen=6, gen=3):
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = np.zeros((MEM_LEN, cfg.d_model), np.float32)
    return Request(rid=rid, tokens=(np.arange(plen) % 7 + 1)
                   .astype(np.int32), gen=gen, **kw)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_zoo_one_admit_two_decodes(zoo, arch):
    """The serving floor: admit + 2 decode steps on every LM config."""
    model, params = zoo(arch)
    eng = _engine(model, params)
    eng.begin()
    eng.admit(_req(model.cfg), slot=0)
    assert len(eng.slots[0].out) == 1             # prefill token
    eng.decode_step()
    retired = eng.decode_step()
    assert len(eng.slots[0].out) == 3 and retired == [0]
    comp = eng.retire(0)
    assert comp.tokens.shape == (3,)
    assert all(0 <= t < model.cfg.vocab_size for t in comp.tokens)
    assert eng.slots[0].free


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_zoo_engine_full_forward_parity(zoo, arch):
    """The serving oracle: every config's engine output is token-identical
    to its own full-sequence greedy forward — across mixed prompt/gen
    lengths so slots refill mid-flight (KV, recurrent-state, MoE and
    cross-attn slot paths all covered by the one assertion)."""
    model, params = zoo(arch)
    cfg = model.cfg
    rng = np.random.RandomState(7)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = rng.randn(MEM_LEN, cfg.d_model).astype(np.float32)
    reqs = [Request(rid=i, tokens=rng.randint(
        0, cfg.vocab_size, size=p).astype(np.int32), gen=g, **kw)
        for i, (p, g) in enumerate([(5, 3), (9, 4), (4, 2)])]
    eng = _engine(model, params, n_slots=2, max_len=32)
    comps = eng.run(reqs)
    assert eng.contract == cache_contract(cfg)
    for req, c in zip(reqs, comps):
        assert len(c.tokens) == req.gen
        assert greedy_chain_ok(model, params, req, c.tokens), req.rid


@pytest.mark.parametrize("arch", _gap_params("prefix_ineligible"))
def test_zoo_prefix_cache_eligibility(zoo, arch):
    """Prefix-cached serving works exactly where the slot-cache contract
    is replayable — pure global-attention KV rewind OR whole-prefix
    recurrent state snapshots (rwkv6/jamba); everywhere else the
    front-end refuses the cache up front (xfail, reason formatted from
    the shared error table)."""
    model, params = zoo(arch)
    eng = _engine(model, params, max_len=48)
    if model.cfg.family == "encdec":
        # eligible-looking stack but excluded: encoder memory keys the
        # cross attention, not the prompt tokens alone
        assert not eng.prefix_eligible()
        pytest.skip("enc-dec is prefix-ineligible by design (cross-attn)")
    fe = ServeFrontend(eng, queue_depth=4, prefix_cache=PrefixCache(),
                       clock=lambda: 0.0)         # raises on gap archs
    shared = (np.arange(8) % 5 + 1).astype(np.int32)
    for i in range(2):
        fe.submit(Request(rid=i, tokens=np.concatenate(
            [shared, np.full((2,), 9 + i, np.int32)]), gen=2))
        while fe.step():
            pass
    assert all(h.status is Status.DONE for h in fe.handles.values())
    assert fe.prefix_cache.hits == 1              # second request reuses
    if eng.contract == "recurrent":
        assert isinstance(eng.slotcache, RecurrentSlotCache)
        assert eng.stats["prefix_hits"] == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_zoo_routed_admit_two_decodes(zoo, arch):
    """The fleet serving floor: every LM config serves one admit + two
    decode steps per replica through a 2-replica router (least-loaded
    spreads the two requests one per replica)."""
    model, params = zoo(arch)
    engines = [_engine(model, params) for _ in range(2)]
    router = ReplicaRouter(engines)
    router.begin(0.0)
    gids = []
    for rid in range(2):
        gid = router.free_slots()[0]
        router.admit(_req(model.cfg, rid=rid), gid)
        gids.append(gid)
    assert [e.active_count() for e in engines] == [1, 1]
    router.decode_step()
    retired = router.decode_step()
    assert sorted(retired) == sorted(gids)
    for gid in retired:
        comp = router.retire(gid)
        assert comp.tokens.shape == (3,)
        assert all(0 <= t < model.cfg.vocab_size for t in comp.tokens)
    assert router.active_count() == 0
    assert all(s.free for e in engines for s in e.slots)


@pytest.mark.parametrize("arch", _shard_params())
def test_zoo_sharded_admit_two_decodes(zoo, arch):
    """The mesh-sharded serving floor over the whole zoo: every LM config
    either takes one sharded admit + two decode steps on a live 2-device
    (1 data x 2 model) mesh, or refuses up front with the single-sourced
    ``shard_ineligible`` message (strict-xfail rows). The deviceless
    ``slot_specs`` call decides both: it raises for every SHARD_GAPS row,
    and for eligible rows the live run happens in a fresh subprocess (the
    forced device count must precede jax init — ``subprocess`` marker)."""
    model, params = zoo(arch)
    sc = _engine(model, params).slotcache
    slot_specs(sc._template, sc.batch_axes, {"model": SHARD_MESH_M},
               name=model.cfg.name)       # <- the eligibility decision
    from test_serve_sharded import run_py
    out = run_py(f"""
import jax, numpy as np
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.serve import ServeEngine, ServeSharding
from repro.serve.engine import Request
from helpers import tiny_cfg

assert len(jax.devices()) == 2
cfg = tiny_cfg({arch!r})
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
kw = dict(n_slots=1, max_len=32)
rkw = {{}}
if cfg.family == "encdec":
    kw["mem_len"] = 8
    rkw["frames"] = np.zeros((8, cfg.d_model), np.float32)
eng = ServeEngine(model, params,
                  sharding=ServeSharding(make_mesh((1, 2))), **kw)
eng.begin()
eng.admit(Request(rid=0, tokens=(np.arange(6) % 7 + 1).astype(np.int32),
                  gen=3, **rkw), slot=0)
assert len(eng.slots[0].out) == 1
eng.decode_step()
retired = eng.decode_step()
assert len(eng.slots[0].out) == 3 and retired == [0]
comp = eng.retire(0)
assert comp.tokens.shape == (3,)
assert all(0 <= t < cfg.vocab_size for t in comp.tokens)
assert eng.slots[0].free
# the decode really ran over a model-split cache, not a replicated one
assert any("model" in tuple(s) for s in jax.tree_util.tree_leaves(
    eng.slotcache.specs, is_leaf=lambda s: isinstance(s, tuple)))
print("OK")
""", devices=2)
    assert "OK" in out


@pytest.mark.parametrize("arch", _gap_params("affinity_ineligible"))
def test_zoo_prefix_affinity_eligibility(zoo, arch):
    """Prefix-affinity routing is constructible exactly where the prefix
    cache is sound (the router refuses it elsewhere — xfail matrix), and
    on eligible stacks the second shared-prefix admit sticks to the warm
    replica."""
    model, params = zoo(arch)
    engines = [_engine(model, params, max_len=48) for _ in range(2)]
    if model.cfg.family == "encdec":
        assert not engines[0].prefix_eligible()
        pytest.skip("enc-dec is prefix-ineligible by design (cross-attn)")
    router = ReplicaRouter(engines, route="prefix-affinity")
    router.begin(0.0)
    shared = (np.arange(8) % 5 + 1).astype(np.int32)
    for i in range(2):
        gid = router.free_slots()[0]
        router.admit(Request(rid=i, tokens=np.concatenate(
            [shared, np.full((2,), 9 + i, np.int32)]), gen=2), gid)
        router.decode_step()
        router.retire(gid)
    # both admits landed on replica 0: the first primed its cache, the
    # second followed the prefix instead of the least-loaded tie-break
    assert engines[0].stats["admits"] == 2
    assert engines[1].stats["admits"] == 0
    assert router.rstats["affinity_hits"] == 1


@pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-1.5-large-398b"])
def test_zoo_recurrent_slot_bytes_constant(zoo, arch):
    """Recurrent slot state is O(1) in sequence budget: doubling max_len
    must not grow a pure-recurrent stack's per-slot bytes at all, and a
    hybrid's (jamba: attn rows still grow) strictly slower than a
    pure-KV stack's — the serving win the recurrent contract buys;
    bench_serve gates the same invariant with a KV reference column."""
    model, params = zoo(arch)
    small = _engine(model, params, max_len=32)
    large = _engine(model, params, max_len=64)
    assert small.contract == "recurrent"
    growth = large.slotcache.slot_bytes / small.slotcache.slot_bytes
    kv_model, kv_params = zoo("qwen2-1.5b")
    kv_s = _engine(kv_model, kv_params, max_len=32)
    kv_l = _engine(kv_model, kv_params, max_len=64)
    kv_growth = kv_l.slotcache.slot_bytes / kv_s.slotcache.slot_bytes
    if set(model.cfg.layer_kinds) <= {"rwkv", "mamba"}:
        assert growth == 1.0                      # no KV rows at all
    assert growth < kv_growth                     # strictly sublinear
    assert kv_growth > 1.5                        # the KV reference grows


@pytest.mark.parametrize("arch", DEIT_IDS[:1])
def test_vit_has_no_serving_path(arch):
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    with pytest.raises(ValueError, match=re.escape(
            errors.msg("no_serving_path", name=cfg.name,
                       family=cfg.family))):
        ServeEngine(model, model.init(jax.random.PRNGKey(0)),
                    n_slots=1, max_len=32)
