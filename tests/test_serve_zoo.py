"""Config-zoo serving smoke: every LM config in ``repro.configs`` must
admit one request and take two decode steps through the slot engine.

The zoo spans pure-attn, sliding-window, recurrent (rwkv), hybrid
(jamba), MoE and enc-dec stacks; serving regressions historically hid in
the configs the serve tests didn't cover. The *ragged/prefix* features are
only sound on pure causal global attention — those gaps are expressed as
``xfail(strict=True)`` entries whose reasons mirror the engine's actual
``ValueError`` text, so a silently widening (or narrowing) feature surface
flips a test and forces this file to be updated deliberately.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.configs import ARCH_IDS, DEIT_IDS
from repro.models import build_model
from repro.serve import (PrefixCache, ReplicaRouter, ServeEngine,
                         ServeFrontend, Status)
from repro.serve.engine import Request

MEM_LEN = 8        # enc-dec encoder-memory length used throughout

# configs whose stacks break the "cache row i is a pure function of tokens
# <= i" premise; reasons mirror the engine's ValueError wording
RAGGED_GAPS = {
    "gemma3-1b": "swa ring buffer: needs a pure global-attention stack",
    "rwkv6-3b": "recurrent state: needs a pure global-attention stack",
    "jamba-1.5-large-398b": ("hybrid attn+ssm stack: needs a pure "
                             "global-attention stack"),
}


@pytest.fixture(scope="module")
def zoo():
    """Lazy per-arch (model, params) cache shared across this module."""
    built = {}

    def get(arch):
        if arch not in built:
            cfg = tiny_cfg(arch)
            model = build_model(cfg)
            built[arch] = (model, model.init(jax.random.PRNGKey(0)))
        return built[arch]

    return get


def _engine(model, params, n_slots=1, max_len=32):
    kw = dict(n_slots=n_slots, max_len=max_len)
    if model.cfg.family == "encdec":
        kw["mem_len"] = MEM_LEN
    return ServeEngine(model, params, **kw)


def _req(cfg, rid=0, plen=6, gen=3):
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = np.zeros((MEM_LEN, cfg.d_model), np.float32)
    return Request(rid=rid, tokens=(np.arange(plen) % 7 + 1)
                   .astype(np.int32), gen=gen, **kw)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_zoo_one_admit_two_decodes(zoo, arch):
    """The serving floor: admit + 2 decode steps on every LM config."""
    model, params = zoo(arch)
    eng = _engine(model, params)
    eng.begin()
    eng.admit(_req(model.cfg), slot=0)
    assert len(eng.slots[0].out) == 1             # prefill token
    eng.decode_step()
    retired = eng.decode_step()
    assert len(eng.slots[0].out) == 3 and retired == [0]
    comp = eng.retire(0)
    assert comp.tokens.shape == (3,)
    assert all(0 <= t < model.cfg.vocab_size for t in comp.tokens)
    assert eng.slots[0].free


@pytest.mark.parametrize(
    "arch",
    [pytest.param(a, marks=pytest.mark.xfail(
        reason=RAGGED_GAPS[a], strict=True)) if a in RAGGED_GAPS
     else a for a in ARCH_IDS])
def test_zoo_prefix_cache_eligibility(zoo, arch):
    """Prefix-cached serving works exactly where ragged prefill is sound;
    everywhere else the front-end refuses the cache up front (xfail,
    reason mirroring the ValueError)."""
    model, params = zoo(arch)
    eng = _engine(model, params, max_len=48)
    if model.cfg.family == "encdec":
        # eligible-looking stack but excluded: encoder memory keys the
        # cross attention, not the prompt tokens alone
        assert not eng.prefix_eligible()
        pytest.skip("enc-dec is prefix-ineligible by design (cross-attn)")
    fe = ServeFrontend(eng, queue_depth=4, prefix_cache=PrefixCache(),
                       clock=lambda: 0.0)         # raises on gap archs
    shared = (np.arange(8) % 5 + 1).astype(np.int32)
    for i in range(2):
        fe.submit(Request(rid=i, tokens=np.concatenate(
            [shared, np.full((2,), 9 + i, np.int32)]), gen=2))
        while fe.step():
            pass
    assert all(h.status is Status.DONE for h in fe.handles.values())
    assert fe.prefix_cache.hits == 1              # second request reuses


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_zoo_routed_admit_two_decodes(zoo, arch):
    """The fleet serving floor: every LM config serves one admit + two
    decode steps per replica through a 2-replica router (least-loaded
    spreads the two requests one per replica)."""
    model, params = zoo(arch)
    engines = [_engine(model, params) for _ in range(2)]
    router = ReplicaRouter(engines)
    router.begin(0.0)
    gids = []
    for rid in range(2):
        gid = router.free_slots()[0]
        router.admit(_req(model.cfg, rid=rid), gid)
        gids.append(gid)
    assert [e.active_count() for e in engines] == [1, 1]
    router.decode_step()
    retired = router.decode_step()
    assert sorted(retired) == sorted(gids)
    for gid in retired:
        comp = router.retire(gid)
        assert comp.tokens.shape == (3,)
        assert all(0 <= t < model.cfg.vocab_size for t in comp.tokens)
    assert router.active_count() == 0
    assert all(s.free for e in engines for s in e.slots)


@pytest.mark.parametrize(
    "arch",
    [pytest.param(a, marks=pytest.mark.xfail(
        reason=RAGGED_GAPS[a], strict=True)) if a in RAGGED_GAPS
     else a for a in ARCH_IDS])
def test_zoo_prefix_affinity_eligibility(zoo, arch):
    """Prefix-affinity routing is constructible exactly where the prefix
    cache is sound (the router refuses it elsewhere — xfail matrix), and
    on eligible stacks the second shared-prefix admit sticks to the warm
    replica."""
    model, params = zoo(arch)
    engines = [_engine(model, params, max_len=48) for _ in range(2)]
    if model.cfg.family == "encdec":
        assert not engines[0].prefix_eligible()
        pytest.skip("enc-dec is prefix-ineligible by design (cross-attn)")
    router = ReplicaRouter(engines, route="prefix-affinity")
    router.begin(0.0)
    shared = (np.arange(8) % 5 + 1).astype(np.int32)
    for i in range(2):
        gid = router.free_slots()[0]
        router.admit(Request(rid=i, tokens=np.concatenate(
            [shared, np.full((2,), 9 + i, np.int32)]), gen=2), gid)
        router.decode_step()
        router.retire(gid)
    # both admits landed on replica 0: the first primed its cache, the
    # second followed the prefix instead of the least-loaded tie-break
    assert engines[0].stats["admits"] == 2
    assert engines[1].stats["admits"] == 0
    assert router.rstats["affinity_hits"] == 1


@pytest.mark.parametrize("arch", DEIT_IDS[:1])
def test_vit_has_no_serving_path(arch):
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    with pytest.raises(ValueError, match="no serving path"):
        ServeEngine(model, model.init(jax.random.PRNGKey(0)),
                    n_slots=1, max_len=32)
