"""Fault-injection tests for the multi-replica ``ReplicaRouter``.

Every failure path the router promises (docs/serving.md "Multi-replica
routing") gets a deterministic test over ``FleetFakeEngine`` replicas:
death mid-decode (no token loss before the failure point), death during
prefill, double-kill, drain-then-kill, and deadline expiry of a request
orphaned awaiting re-dispatch — each landing exactly one terminal status.
The real-engine test drives a 2-replica fleet of jitted engines through a
kill and asserts the re-dispatched streams stay token-identical to a
single engine (the greedy-determinism argument, exercised on real math).
"""
from __future__ import annotations

import re

import jax
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.models import build_model
from repro.serve import (ReplicaRouter, ReplicaState, ServeEngine,
                         ServeFrontend, Status, errors, frontend_table,
                         synthetic_trace)
from repro.serve.engine import Request
from repro.serve.router import ROUTES
from repro.serve.testing import FleetFakeEngine, ManualClock, fleet_token


def _req(rid, plen=3, gen=4, deadline=None):
    return Request(rid=rid, tokens=np.arange(1, plen + 1, dtype=np.int32),
                   gen=gen, deadline=deadline)


def _fleet(n_replicas, slots, **kw):
    engines = [FleetFakeEngine(slots, **kw) for _ in range(n_replicas)]
    return engines, ReplicaRouter(engines)


def _stream(rid, n):
    return [fleet_token(rid, i) for i in range(n)]


# ---------------------------------------------------------------------------
# construction / validation
# ---------------------------------------------------------------------------

def test_router_validation():
    with pytest.raises(ValueError, match=re.escape(
            errors.msg("router_needs_engines"))):
        ReplicaRouter([])
    with pytest.raises(ValueError, match=re.escape(
            errors.msg("unknown_route", route="round-robin",
                       routes=ROUTES))):
        ReplicaRouter([FleetFakeEngine(1)], route="round-robin")
    # prefix-affinity needs a prefix-eligible stack
    with pytest.raises(ValueError, match=re.escape(
            errors.msg("affinity_ineligible",
                       name=FleetFakeEngine(1).cfg.name))):
        ReplicaRouter([FleetFakeEngine(1)], route="prefix-affinity")
    r = ReplicaRouter([FleetFakeEngine(1, prefix_ok=True)],
                      route="prefix-affinity")
    assert r.prefix_eligible()


# ---------------------------------------------------------------------------
# death mid-decode: re-dispatch with zero token loss
# ---------------------------------------------------------------------------

def test_death_mid_decode_no_token_loss():
    """Tokens produced before the failing step survive the re-dispatch and
    the continued stream is exact — no gap, no duplicate."""
    engines, router = _fleet(2, 1)
    fe = ServeFrontend(router, queue_depth=8, clock=ManualClock())
    h0, h1 = fe.submit(_req(0, gen=6)), fe.submit(_req(1, gen=3))
    fe.step()                               # both decode one token
    engines[0].fail_next_decode = True
    while not (h0.finished and h1.finished):
        fe.step()
    assert h0.status is Status.DONE and h1.status is Status.DONE
    assert h0.tokens == _stream(0, 6)
    assert h1.tokens == _stream(1, 3)
    assert router.rstats["redispatches"] == 1
    assert router.rstats["orphaned"] == 1
    assert router.states == [ReplicaState.DOWN, ReplicaState.UP]


def test_death_during_prefill_retries_on_survivor():
    """admit raising marks the replica DOWN and the same admit lands on
    the next survivor — the caller never sees the exception."""
    engines, router = _fleet(2, 1)
    engines[0].fail_next_admit = True       # least-loaded would pick 0
    fe = ServeFrontend(router, queue_depth=8, clock=ManualClock())
    h = fe.submit(_req(0, gen=3))
    while not h.finished:
        fe.step()
    assert h.status is Status.DONE and h.tokens == _stream(0, 3)
    assert router.states == [ReplicaState.DOWN, ReplicaState.UP]
    assert engines[1].stats["admits"] == 1
    assert router.rstats["replicas_down"] == 1


def test_double_kill_is_idempotent():
    engines, router = _fleet(2, 1)
    fe = ServeFrontend(router, queue_depth=8, clock=ManualClock())
    h = fe.submit(_req(0, gen=5))
    fe.step()
    router.kill(0)
    router.kill(0)                          # second kill: no-op
    while not h.finished:
        fe.step()
    assert h.status is Status.DONE and h.tokens == _stream(0, 5)
    assert router.rstats["replicas_down"] == 1
    assert router.rstats["orphaned"] == 1
    assert router.rstats["redispatches"] == 1


def test_drain_then_kill_redispatches_in_flight():
    """Killing a DRAINING replica orphans its in-flight requests like any
    other death; they finish on survivors and the replica stays DOWN
    (not drained — it was removed by failure, not by completion)."""
    engines, router = _fleet(2, 2)
    fe = ServeFrontend(router, queue_depth=8, clock=ManualClock())
    hs = [fe.submit(_req(i, gen=5)) for i in range(3)]
    fe.step()                               # rid 0,2 on replica 0; rid 1 on 1
    router.drain(0)
    router.kill(0)
    while not all(h.finished for h in hs):
        fe.step()
    for h in hs:
        assert h.status is Status.DONE
        assert h.tokens == _stream(h.rid, 5)
    assert router.states[0] is ReplicaState.DOWN
    assert not router.drained(0)
    assert router.rstats["orphaned"] == 2


def test_deadline_expiry_of_orphaned_request():
    """A request orphaned by replica death (survivors busy, so it waits
    PENDING) whose deadline passes is EXPIRED exactly once, keeping the
    tokens produced before the death."""
    engines, router = _fleet(2, 1)
    clk = ManualClock()
    fe = ServeFrontend(router, queue_depth=8, clock=clk)
    h0 = fe.submit(_req(0, gen=20, deadline=5.0))
    h1 = fe.submit(_req(1, gen=20))
    fe.step()                               # both have 2 tokens
    router.kill(0)                          # h0 orphaned; replica 1 busy
    fe.step()
    assert not h0.finished                  # waiting PENDING, not failed
    clk.advance(10.0)                       # past h0's deadline
    fe.step()
    assert h0.status is Status.EXPIRED
    assert h0.tokens == _stream(0, 2)       # pre-death tokens kept
    while not h1.finished:
        fe.step()
    assert h1.status is Status.DONE and h1.tokens == _stream(1, 20)
    assert router.rstats["redispatches"] == 0


def test_all_replicas_dead_fails_exactly_once():
    """With no survivor the request is finished FAILED once, with its
    partial tokens; take_failed drains exactly once."""
    engines, router = _fleet(2, 1)
    fe = ServeFrontend(router, queue_depth=8, clock=ManualClock())
    h0, h1 = fe.submit(_req(0, gen=6)), fe.submit(_req(1, gen=6))
    fe.step()
    engines[0].fail_next_decode = True
    engines[1].fail_next_decode = True
    for _ in range(4):
        fe.step()
    assert h0.status is Status.FAILED and h1.status is Status.FAILED
    assert h0.tokens == _stream(0, 2)       # pre-death prefix kept
    assert h1.tokens == _stream(1, 2)
    assert router.take_failed() == []       # already reaped, exactly once
    tab = frontend_table([h0, h1], wall=1.0)
    assert tab["failed"] == 2 and tab["done"] == 0


def test_cancel_of_pending_orphan_frees_capacity():
    """Cancelling an orphan waiting for re-dispatch releases its reserved
    seat immediately (regression: a stale deque entry used to keep
    under-reporting free_slots until the next step)."""
    engines, router = _fleet(2, 1)
    router.begin(0.0)
    gid = router.free_slots()[0]
    router.admit(_req(0, gen=6), gid)
    router.kill(0)                          # rid 0 -> PENDING
    assert router.free_slots() == []        # replica 1's seat is reserved
    assert router.cancel(gid) == _stream(0, 1)
    assert len(router.free_slots()) == 1    # seat released at cancel
    router.decode_step()                    # stale-entry guard: no blowup
    assert router.active_count() == 0


def test_queued_requests_flow_to_survivors():
    """Requests still in the admission queue when a replica dies are
    admitted to survivors as slots free up — the queue never sees the
    death."""
    engines, router = _fleet(2, 1)
    fe = ServeFrontend(router, queue_depth=8, clock=ManualClock())
    hs = [fe.submit(_req(i, gen=3)) for i in range(5)]
    fe.step()
    engines[0].fail_next_decode = True
    while not all(h.finished for h in hs):
        fe.step()
    assert all(h.status is Status.DONE for h in hs)
    for h in hs:
        assert h.tokens == _stream(h.rid, 3)
    assert engines[0].stats["admits"] == 1  # only the pre-death admit


# ---------------------------------------------------------------------------
# prefix-affinity routing
# ---------------------------------------------------------------------------

def test_prefix_affinity_overrides_least_loaded():
    """A prompt whose prefix is cached on a busier replica still routes to
    it; prompts with no cached prefix fall back to least-loaded."""
    engines, router = _fleet(2, 2, prefix_ok=True)
    router2 = ReplicaRouter(engines, route="prefix-affinity")
    shared = np.arange(1, 9, dtype=np.int32)        # 8 tokens >= min_hit
    router2._caches[1].insert(shared, cache="kv", nbytes=8)
    # replica 1 busier than 0: least-loaded alone would pick 0
    router2.admit(_req(5, gen=4), router2.free_slots()[0])
    assert router2.vslots and engines[0].stats["admits"] == 1
    hit = Request(rid=6, tokens=np.concatenate(
        [shared, np.array([99], np.int32)]), gen=4)
    router2.admit(hit, router2.free_slots()[0])
    assert engines[1].stats["admits"] == 1          # affinity won
    assert router2.rstats["affinity_hits"] == 1
    miss = _req(7, plen=2, gen=4)
    router2.admit(miss, router2.free_slots()[0])
    assert engines[0].stats["admits"] == 2          # least-loaded fallback


# ---------------------------------------------------------------------------
# real engines: kill mid-trace, streams stay token-identical
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm():
    cfg = tiny_cfg("qwen2-1.5b")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def test_real_fleet_kill_streams_token_identical(lm):
    """2 real engines behind the router, replica 0 killed mid-trace: every
    re-dispatched stream must equal the single-engine reference — the
    re-prefill overlap token is checked by the router on real argmax."""
    model, params = lm
    trace = synthetic_trace(n=5, seed=7, prompt_range=(4, 8),
                            gen_range=(3, 6), vocab=model.cfg.vocab_size)
    ref_eng = ServeEngine(model, params, n_slots=2, max_len=48)
    ref = ref_eng.run(trace)

    engines = [ServeEngine(model, params, n_slots=2, max_len=48)
               for _ in range(2)]
    router = ReplicaRouter(engines)
    fe = ServeFrontend(router, queue_depth=8)
    handles = [fe.submit(r) for r in trace]
    fe.step()
    fe.step()
    router.kill(0)
    for _ in range(256):
        if not fe.step():
            break
    for h in handles:
        assert h.status is Status.DONE, f"rid {h.rid} ended {h.status}"
        assert h.tokens == [int(t) for t in ref[h.rid].tokens], \
            f"rid {h.rid}: routed stream diverged after kill"
    assert router.states[0] is ReplicaState.DOWN
    assert router.rstats["orphaned"] > 0


# ---------------------------------------------------------------------------
# chunked prefill across replica death (serve/scheduler.py over the fleet)
# ---------------------------------------------------------------------------

def test_chunked_fleet_matches_atomic_streams():
    """The routed front-end with prefill_chunk set streams byte-identically
    to atomic admits — including mid-stream PREFILLING slots skipping
    decode lanes per replica."""
    engines, router = _fleet(2, 2)
    fe = ServeFrontend(router, queue_depth=8, clock=ManualClock(),
                       prefill_chunk=2)
    hs = [fe.submit(_req(i, plen=3 + 2 * i, gen=3 + i)) for i in range(4)]
    for _ in range(128):
        if not fe.step():
            break
    for i, h in enumerate(hs):
        assert h.status is Status.DONE
        assert h.tokens == _stream(i, 3 + i), f"rid {i}"
    assert all(v.free for v in router.vslots)
    assert sum(e.stats["chunk_steps"] for e in engines) > 0


def test_replica_death_mid_chunked_prefill_reprefills_from_prompt():
    """The tentpole's re-dispatch rule: a slot orphaned mid-chunked-prefill
    has ZERO delivered tokens, so the survivor re-prefills from the full
    prompt — greedy determinism keeps the stream byte-identical."""
    engines, router = _fleet(2, 1)
    clk = ManualClock()
    fe = ServeFrontend(router, queue_depth=8, clock=clk, prefill_chunk=2)
    h0 = fe.submit(_req(0, plen=9, gen=4))   # lands replica 0, PREFILLING
    h1 = fe.submit(_req(1, plen=2, gen=6))   # lands replica 1, decoding
    assert h0.status is Status.RUNNING and h0.tokens == []
    router.kill(0)                           # death mid-chunked-prefill
    for _ in range(128):
        if not fe.step():
            break
    assert h0.status is Status.DONE and h0.tokens == _stream(0, 4)
    assert h1.status is Status.DONE and h1.tokens == _stream(1, 6)
    assert router.rstats["orphaned"] == 1
    assert router.rstats["redispatches"] == 1
    # the re-prefill was whole-prompt on the survivor: replica 1 admitted
    # both requests, and no partial chunk state crossed replicas
    assert engines[1].stats["admits"] == 2
    assert all(v.free for v in router.vslots)


def test_real_fleet_chunked_kill_streams_token_identical(lm):
    """Real engines, chunked prefill, replica killed mid-trace: every
    stream equals the single-engine unchunked reference — chunking and
    re-dispatch compose without a single token of drift."""
    model, params = lm
    trace = synthetic_trace(n=5, seed=7, prompt_range=(4, 8),
                            gen_range=(3, 6), vocab=model.cfg.vocab_size)
    ref = ServeEngine(model, params, n_slots=2, max_len=48).run(trace)

    engines = [ServeEngine(model, params, n_slots=2, max_len=48)
               for _ in range(2)]
    router = ReplicaRouter(engines)
    fe = ServeFrontend(router, queue_depth=8, prefill_chunk=3)
    handles = [fe.submit(r) for r in trace]
    fe.step()
    fe.step()
    router.kill(0)
    for _ in range(256):
        if not fe.step():
            break
    for h in handles:
        assert h.status is Status.DONE, f"rid {h.rid} ended {h.status}"
        assert h.tokens == [int(t) for t in ref[h.rid].tokens], \
            f"rid {h.rid}: chunked routed stream diverged after kill"
    assert router.states[0] is ReplicaState.DOWN
