"""End-to-end CORP pipeline tests across all model families.

For every assigned family (reduced config): prune at 50%/50%, assert
  * the pruned model runs and has the reduced dims,
  * compensated output error <= uncompensated output error (the paper's
    central claim, Fig. 2),
  * parameter count strictly decreases,
  * identity at zero sparsity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PruneConfig, corp_prune, discover_units
from repro.models import build_model

from helpers import batch_for, calib_factory, mse, out_of, tiny_cfg

FAMILIES = [
    "deit-base",                 # paper's own arch: class-1 full M
    "granite-8b",                # GQA + rope: class-2
    "gemma3-1b",                 # GQA + rope + qk-norm + swa: class-3
    "qwen2-1.5b",                # QKV bias + rope: class-2 w/ bias fold
    "deepseek-v3-671b",          # MLA + MoE + shared expert
    "qwen3-moe-235b-a22b",       # MoE + qk-norm
    "rwkv6-3b",                  # attention-free: MLP-only
    "jamba-1.5-large-398b",      # hybrid mamba/attn + MoE
    "seamless-m4t-large-v2",     # enc-dec + cross-attn: class-1
    "internvl2-26b",             # VLM stub frontend
    "deepseek-7b",               # plain MHA
]


@pytest.mark.parametrize("arch", FAMILIES)
def test_prune_end_to_end(arch):
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calib_factory(cfg)
    batch = batch_for(cfg, B=2, T=24, seed=77)
    y0 = out_of(model, params, batch)

    errs = {}
    for comp in (True, False):
        pc = PruneConfig(mlp_sparsity=0.5, attn_sparsity=0.5,
                         compensate=comp)
        new_p, new_c, report = corp_prune(model, params, calib, pc)
        m2 = build_model(new_c)
        y1 = out_of(m2, new_p, batch)
        assert np.all(np.isfinite(np.asarray(y1, np.float32)))
        errs[comp] = mse(y1, y0)
        # params decrease
        n0 = sum(x.size for x in jax.tree.leaves(params))
        n1 = sum(x.size for x in jax.tree.leaves(new_p))
        assert n1 < n0
        if comp:
            # per-unit diagnostics present and sane
            for name, d in report["units"].items():
                assert np.all(np.asarray(d["j_star"]) <= np.asarray(
                    d["j_uncomp"]) * (1 + 1e-3) + 1e-6), name
    # The paper's guarantee (Props C.1.2/C.2.2) is on the LAYER-LOCAL fit
    # objective — asserted strictly above (j_star <= j_uncomp per unit).
    # End-to-end output MSE through the inter-layer nonlinearities can
    # wobble a few percent on random-init weights with a tiny calibration
    # set (no real redundancy to exploit); trained-model benchmarks show
    # the expected end-to-end gains (EXPERIMENTS.md fig2).
    assert errs[True] <= errs[False] * 1.25, \
        f"compensation should not hurt: {errs}"


def test_zero_sparsity_is_identity():
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    new_p, new_c, _ = corp_prune(model, params, calib_factory(cfg),
                                 PruneConfig(0.0, 0.0))
    assert new_c.d_ff_kept is None and new_c.qk_kept is None
    batch = batch_for(cfg)
    np.testing.assert_allclose(np.asarray(out_of(model, params, batch)),
                               np.asarray(out_of(model, new_p, batch)),
                               rtol=1e-5, atol=1e-5)


def test_mlp_only_and_attn_only():
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    calib = calib_factory(cfg)
    p_m, c_m, _ = corp_prune(model, params, calib, PruneConfig(0.5, 0.0))
    assert c_m.d_ff_kept is not None and c_m.qk_kept is None
    p_a, c_a, _ = corp_prune(model, params, calib, PruneConfig(0.0, 0.5))
    assert c_a.d_ff_kept is None and c_a.qk_kept is not None
    batch = batch_for(cfg)
    for p, c in ((p_m, c_m), (p_a, c_a)):
        y = out_of(build_model(c), p, batch)
        assert np.all(np.isfinite(np.asarray(y, np.float32)))


def test_rank_policies():
    from repro.core.ranking import POLICIES
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    calib = calib_factory(cfg)
    batch = batch_for(cfg)
    y0 = out_of(model, params, batch)
    for policy in POLICIES:
        p, c, _ = corp_prune(model, params, calib,
                             PruneConfig(0.5, 0.0, rank_policy=policy))
        y = out_of(build_model(c), p, batch)
        assert np.isfinite(mse(y, y0)), policy


def test_round_to_alignment():
    """TPU lane-alignment mode: kept dims forced to multiples of round_to."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    p, c, _ = corp_prune(model, params, calib_factory(cfg),
                         PruneConfig(0.45, 0.45, round_to=8))
    assert c.d_ff_kept % 8 == 0
    assert c.qk_kept % 8 == 0
    y = out_of(build_model(c), p, batch_for(cfg))
    assert np.all(np.isfinite(np.asarray(y, np.float32)))


def test_unit_discovery_counts():
    cfg = tiny_cfg("jamba-1.5-large-398b")
    units = discover_units(cfg)
    kinds = [u.kind for u in units]
    assert "attn" in kinds and "mamba" in kinds and "moe" in kinds \
        and "mlp" in kinds
    cfg2 = tiny_cfg("rwkv6-3b")
    kinds2 = {u.kind for u in discover_units(cfg2)}
    assert kinds2 == {"rwkv_mlp"}, "rwkv is attention-free: QK inapplicable"


def test_pruned_model_decode_consistency():
    """Pruned LM: prefill+decode must equal its own full forward."""
    cfg = tiny_cfg("granite-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    new_p, new_c, _ = corp_prune(model, params, calib_factory(cfg),
                                 PruneConfig(0.5, 0.5))
    m2 = build_model(new_c)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 10), 0,
                              cfg.vocab_size)
    full, _ = m2.apply(new_p, {"tokens": toks})
    lg, cache = m2.prefill(new_p, {"tokens": toks[:, :8]}, 16)
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(full[:, 7]), rtol=2e-3, atol=2e-3)
    for t in range(8, 10):
        lg, cache = m2.decode_step(new_p, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(lg[:, -1]),
                                   np.asarray(full[:, t]), rtol=2e-3,
                                   atol=2e-3)


def test_streamed_prune_matches_full():
    """corp_prune_streamed (bounded-memory, layer-group streaming) must
    produce byte-identical pruned weights to the one-shot corp_prune —
    the statistics are linear, so partitioning the unit set is exact."""
    from repro.core.pruner import corp_prune_streamed
    cfg = tiny_cfg("gemma3-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    calib = calib_factory(cfg, n=3)
    pc = PruneConfig(0.5, 0.5)
    p_full, c_full, _ = corp_prune(model, params, calib, pc)
    p_str, c_str, rep = corp_prune_streamed(model, params, calib, pc,
                                            unit_group_size=1)
    assert c_full == c_str
    assert rep["groups"] > 1
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_str)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-6)
