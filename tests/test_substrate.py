"""Substrate tests: data determinism, optimizer, checkpoint/restart,
fault-tolerant calibration accumulation."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.core.stats import tree_add
from repro.data import lm_batch, vit_batch
from repro.distrib.fault import TolerantAccumulator, remesh
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_by_index():
    a = lm_batch(7, batch=8, seq=32, vocab=101, seed=3)
    b = lm_batch(7, batch=8, seq=32, vocab=101, seed=3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = lm_batch(8, batch=8, seq=32, vocab=101, seed=3)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_data_shards_partition_global_batch():
    full = lm_batch(3, batch=8, seq=16, vocab=64, seed=1)
    s0 = lm_batch(3, batch=8, seq=16, vocab=64, seed=1, shard=0, nshards=2)
    assert s0["tokens"].shape == (4, 16)
    # labels are the shifted tokens
    np.testing.assert_array_equal(np.asarray(full["tokens"][:, 1:]),
                                  np.asarray(full["labels"][:, :-1]))


def test_data_is_learnable_markov():
    """The markov stream must beat the uniform-entropy floor trivially via
    bigram statistics (sanity that tasks are not pure noise)."""
    b = lm_batch(0, batch=16, seq=256, vocab=64, seed=0)
    toks = np.asarray(b["tokens"]).reshape(-1)
    # empirical bigram entropy should be far below log2(64)
    counts = np.zeros((64, 64))
    np.add.at(counts, (toks[:-1], toks[1:]), 1)
    p = counts / np.maximum(counts.sum(1, keepdims=True), 1)
    rowent = -(p * np.log2(np.maximum(p, 1e-12))).sum(1)
    w = counts.sum(1) / counts.sum()
    assert (rowent * w).sum() < 4.5  # << 6 bits uniform


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([4.0, -3.0]), "rope_inv_q": jnp.ones(2)}
    ocfg = AdamWConfig(weight_decay=0.0, clip_norm=0.0)
    opt = adamw_init(params, ocfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, 0.05, ocfg)
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=1e-2)
    # frozen buffer untouched
    np.testing.assert_array_equal(np.asarray(params["rope_inv_q"]),
                                  np.ones(2))


def test_adamw_clipping_and_schedule():
    s = [float(warmup_cosine(t, peak=1.0, warmup=10, total=100))
         for t in [0, 5, 10, 50, 100]]
    assert s[0] == 0.0 and s[1] == pytest.approx(0.5)
    assert s[2] == pytest.approx(1.0)
    assert s[2] > s[3] > s[4] >= 0.1 - 1e-6
    params = {"w": jnp.zeros(3)}
    ocfg = AdamWConfig(clip_norm=1.0)
    opt = adamw_init(params, ocfg)
    g = {"w": jnp.full((3,), 100.0)}
    _, _, m = adamw_update(params, g, opt, 1e-3, ocfg)
    assert float(m["grad_norm"]) > 100.0   # raw norm reported


def test_adamw_bf16_m_state():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    ocfg = AdamWConfig(m_dtype="bfloat16")
    opt = adamw_init(params, ocfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    p2, o2, _ = adamw_update(params, g, opt, 1e-2, ocfg)
    assert o2["m"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,), jnp.float32)},
            "step": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, extra = restore_checkpoint(str(tmp_path), 7, like)
    assert extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_skips_corrupt(tmp_path):
    tree = {"a": jnp.ones(3)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    # corrupt step 2
    with open(os.path.join(str(tmp_path), "step_00000002", "arrays.npz"),
              "wb") as f:
        f.write(b"garbage")
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save(s, {"a": jnp.full((2,), float(s))})
    ck.wait()
    assert latest_step(str(tmp_path)) == 3
    steps = sorted(n for n in os.listdir(str(tmp_path))
                   if n.startswith("step_"))
    assert len(steps) == 2        # gc kept last 2


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_tolerant_accumulator_reweights():
    """Dropping batches yields an unbiased mean after n-reweighting."""
    def step(params, batch):
        x = batch["x"]
        return {"n": jnp.asarray(float(x.shape[0])), "s1": jnp.sum(x, 0)}

    rng = np.random.RandomState(0)
    batches = [{"x": jnp.asarray(rng.randn(16, 4).astype(np.float32) + 2.0)}
               for _ in range(20)]

    def fail_some(i):
        if i in (3, 7, 11):
            raise RuntimeError("simulated host loss")

    acc = TolerantAccumulator(step, None, fail_hook=fail_some)
    tot = acc.run(batches)
    assert acc.n_failed == 3 and acc.n_ok == 17
    mean = np.asarray(tot["s1"]) / float(tot["n"])
    np.testing.assert_allclose(mean, 2.0, atol=0.2)


def test_restart_loop_resumes(tmp_path):
    calls = {"fails": 0}

    def make_state():
        return {"x": jnp.zeros(())}

    def step_fn(state, step):
        if step == 5 and calls["fails"] == 0:
            calls["fails"] += 1
            raise RuntimeError("simulated crash")
        return {"x": state["x"] + 1.0}

    from repro.distrib.fault import run_with_restarts
    final = run_with_restarts(make_state, step_fn, ckpt_dir=str(tmp_path),
                              total_steps=10, save_every=2)
    # crash at step 5 -> restart from the step-4 checkpoint -> x ends at 10
    assert float(final["x"]) == 10.0
    assert calls["fails"] == 1


def test_remesh_builds_valid_mesh():
    m = remesh()
    assert m.devices.size == len(jax.devices())


# ---------------------------------------------------------------------------
# gradient compression (error feedback int8)
# ---------------------------------------------------------------------------

def test_ef_int8_compression_converges():
    """EF-int8 compressed AdamW must still solve the quadratic (the residual
    feedback telescopes the quantization bias away)."""
    from repro.optim.compress import ef_init, ef_round_trip
    params = {"w": jnp.asarray([4.0, -3.0, 2.0])}
    ocfg = AdamWConfig(weight_decay=0.0, clip_norm=0.0)
    opt = adamw_init(params, ocfg)
    ef = ef_init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))

    for _ in range(400):
        g = jax.grad(loss)(params)
        g, ef = ef_round_trip(g, ef)
        params, opt, _ = adamw_update(params, g, opt, 0.05, ocfg)
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=3e-2)


def test_ef_int8_unbiased_over_time():
    """Sum of dequantized grads + final residual == sum of true grads."""
    from repro.optim.compress import ef_init, ef_round_trip
    rng = np.random.RandomState(0)
    tree = {"a": jnp.zeros((32,))}
    ef = ef_init(tree)
    total_true = np.zeros(32)
    total_sent = np.zeros(32)
    for i in range(50):
        g = {"a": jnp.asarray(rng.randn(32).astype(np.float32))}
        total_true += np.asarray(g["a"])
        sent, ef = ef_round_trip(g, ef)
        total_sent += np.asarray(sent["a"])
    resid = np.asarray(ef["a"])
    np.testing.assert_allclose(total_sent + resid, total_true, atol=1e-3)
