"""CalibrationEngine: fused-vs-legacy parity, pipeline oracle, resumability.

The engine must be a pure refactor of the statistics path: identical
statistics to the legacy host-loop accumulate (same linear reductions, one
fused forward instead of per-unit steps), an exact-identity pipeline at
zero sparsity, and checkpoint/resume that reproduces an uninterrupted pass
bit-for-bit (batches are deterministic-by-index).
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CalibrationEngine, PruneConfig, corp_prune,
                        discover_units)
from repro.core import stats as stats_mod
from repro.core.pruner import accumulate
from repro.core.ranking import rank_attn
from repro.distrib.fault import CalibrationCheckpointer
from repro.models import build_model

from helpers import batch_for, calib_factory, out_of, tiny_cfg


def _assert_tree_close(a, b, rtol=1e-5, atol=1e-5):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=rtol, atol=atol), a, b)


# ---------------------------------------------------------------------------
# engine vs legacy statistics parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["deit-base", "granite-8b"])
def test_engine_matches_legacy_pass1(arch):
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calib_factory(cfg, n=3)
    units = discover_units(cfg)
    fused = CalibrationEngine(model, units, phase=1).run(params, calib())
    legacy = accumulate(stats_mod.make_stats_step(model, units, phase=1),
                        params, calib())
    _assert_tree_close(fused, legacy)


@pytest.mark.parametrize("arch", ["deit-base", "granite-8b"])
def test_engine_matches_legacy_pass2(arch):
    """Pass 2 (attention ridge inputs, complex for rope archs) must agree
    given the same keep/prune plan."""
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    calib = calib_factory(cfg, n=3)
    units = discover_units(cfg)
    p1 = CalibrationEngine(model, units, phase=1).run(params, calib())
    plan = {}
    for u in units:
        if u.kind in ("attn", "mla", "cross"):
            full = p1[u.name]["rank"].shape[-1]
            plan[u.name] = rank_attn(p1[u.name], max(1, full // 2))
    assert plan, arch
    fused = CalibrationEngine(model, units, phase=2, plan=plan) \
        .run(params, calib())
    legacy = accumulate(
        stats_mod.make_stats_step(
            model, units, phase=2,
            plan={k: tuple(map(jnp.asarray, v)) for k, v in plan.items()}),
        params, calib())
    _assert_tree_close(fused, legacy, rtol=1e-4, atol=1e-4)


def test_engine_per_unit_partition_is_exact():
    """Statistics are linear: gathering units one at a time (the per-unit
    loop the engine replaces) must equal the single fused forward."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    calib = calib_factory(cfg, n=2)
    units = discover_units(cfg)
    fused = CalibrationEngine(model, units, phase=1).run(params, calib())
    per_unit = {}
    for u in units:
        per_unit.update(
            CalibrationEngine(model, [u], phase=1).run(params, calib()))
    _assert_tree_close(fused, per_unit)


# ---------------------------------------------------------------------------
# pipeline oracle
# ---------------------------------------------------------------------------

def test_zero_sparsity_params_bitwise_identical():
    """corp_prune at 0/0 sparsity must return numerically identical params
    (no unit enters the plan, so weights pass through untouched) and report
    zero distortion."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    new_p, new_c, report = corp_prune(model, params,
                                      calib_factory(cfg, n=2),
                                      PruneConfig(0.0, 0.0))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert new_c.d_ff_kept is None and new_c.qk_kept is None
    # zero distortion: nothing was pruned, so no unit reports any
    total = sum(float(np.sum(np.abs(np.asarray(d["j_star"]))))
                for d in report["units"].values())
    assert total == 0.0
    y0 = out_of(model, params, batch_for(cfg))
    y1 = out_of(build_model(new_c), new_p, batch_for(cfg))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


def test_prune_via_engine_runs_end_to_end():
    """Smoke: the engine-backed corp_prune produces a working smaller model
    with sane diagnostics (full-pipeline oracle on one family)."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    new_p, new_c, report = corp_prune(model, params, calib_factory(cfg),
                                      PruneConfig(0.5, 0.5))
    y = out_of(build_model(new_c), new_p, batch_for(cfg))
    assert np.all(np.isfinite(np.asarray(y, np.float32)))
    for name, d in report["units"].items():
        assert np.all(np.asarray(d["j_star"]) <= np.asarray(d["j_uncomp"])
                      * (1 + 1e-3) + 1e-6), name


# ---------------------------------------------------------------------------
# resumability / fault tolerance
# ---------------------------------------------------------------------------

def test_checkpoint_resume_reproduces_uninterrupted_pass(tmp_path):
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    calib = calib_factory(cfg, n=4)
    units = discover_units(cfg)
    eng = CalibrationEngine(model, units, phase=1)
    ref = eng.run(params, calib())

    ckdir = str(tmp_path / "calib")
    # simulate a host dying after 2 of 4 batches (checkpoint every batch)
    eng.run(params, itertools.islice(calib(), 2),
            checkpointer=CalibrationCheckpointer(ckdir, every=1))
    # restart: the engine resumes at batch 2 and must land on identical sums
    resumed = eng.run(params, calib(),
                      checkpointer=CalibrationCheckpointer(ckdir, every=1))
    _assert_tree_close(resumed, ref, rtol=1e-6, atol=1e-6)


def test_corp_prune_with_ckpt_dir(tmp_path):
    """End-to-end: ckpt_dir threads through both passes and a re-run picks
    the checkpoints up (same pruned params either way)."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    calib = calib_factory(cfg, n=3)
    pc = PruneConfig(0.5, 0.5)
    p_ref, c_ref, _ = corp_prune(model, params, calib, pc)
    ckdir = str(tmp_path / "prune")
    p1, c1, _ = corp_prune(model, params, calib, pc, ckpt_dir=ckdir,
                           ckpt_every=1)
    p2, c2, _ = corp_prune(model, params, calib, pc, ckpt_dir=ckdir,
                           ckpt_every=1)   # fully resumes from checkpoints
    assert c_ref == c1 == c2
    for a, b, c in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p1),
                       jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_fail_hook_drops_batches_gracefully():
    """Bounded-staleness mode: a failing batch shrinks n but keeps the
    estimator usable (fault.py mechanism 2 through the engine)."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    calib = calib_factory(cfg, n=4)
    units = discover_units(cfg)
    eng = CalibrationEngine(model, units, phase=1)

    def hook(i):
        if i == 1:
            raise RuntimeError("simulated lost host")

    full = eng.run(params, calib())
    degraded = eng.run(params, calib(), fail_hook=hook)
    mlp = [u.name for u in units if u.kind == "mlp"][0]
    n_full = float(np.asarray(full[mlp]["n"]).ravel()[0])
    n_deg = float(np.asarray(degraded[mlp]["n"]).ravel()[0])
    assert n_deg == pytest.approx(n_full * 3 / 4)
    # all batches failing is an error
    with pytest.raises(ValueError):
        eng.run(params, calib(),
                fail_hook=lambda i: (_ for _ in ()).throw(RuntimeError()))


def test_checkpoint_fingerprint_rejects_foreign_config(tmp_path):
    """A reused --calib-ckpt dir from a different pass/plan must be ignored
    (fresh start), never silently resumed into wrong statistics."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(8))
    calib = calib_factory(cfg, n=3)
    units = discover_units(cfg)
    mlp_only = [u for u in units if u.kind == "mlp"]
    ckdir = str(tmp_path / "reused")

    eng_a = CalibrationEngine(model, mlp_only, phase=1)
    eng_b = CalibrationEngine(model, units, phase=1)
    assert eng_a.fingerprint != eng_b.fingerprint
    eng_a.run(params, calib(),
              checkpointer=CalibrationCheckpointer(ckdir, every=1))
    # same dir, different unit set: checkpoint has a foreign fingerprint
    # (and a foreign tree) — must start fresh and still match a clean run
    out = eng_b.run(params, calib(),
                    checkpointer=CalibrationCheckpointer(ckdir, every=1))
    ref = eng_b.run(params, calib())
    _assert_tree_close(out, ref, rtol=1e-6, atol=1e-6)

    # pass-2 fingerprints must differ when only the plan differs
    p1 = eng_b.run(params, calib())
    attn = [u for u in units if u.kind == "attn"][0]
    full = p1[attn.name]["rank"].shape[-1]
    plan_a = {attn.name: rank_attn(p1[attn.name], max(1, full // 2))}
    plan_b = {attn.name: rank_attn(p1[attn.name], max(1, full // 4))}
    e2a = CalibrationEngine(model, units, phase=2, plan=plan_a)
    e2b = CalibrationEngine(model, units, phase=2, plan=plan_b)
    assert e2a.fingerprint != e2b.fingerprint
