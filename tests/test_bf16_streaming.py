"""bf16 activation streaming (`stats_dtype="bfloat16"`): Sigma tolerance on
ill-conditioned inputs, engine parity, fingerprint separation, and the
zero-sparsity pipeline oracle under the bf16 stream.

The invariant: every statistic ACCUMULATES fp32 regardless of the streaming
dtype — bf16 only rounds each tapped activation once (8-bit mantissa,
~0.4% per entry), so second moments must track the fp32 stream to ~1e-2
relative to their largest entry (the documented tolerance, docs/kernels.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CalibrationEngine, PruneConfig, corp_prune, \
    discover_units
from repro.kernels.gram import ops as gops
from repro.models import build_model
from repro.models import common as model_common

from helpers import batch_for, calib_factory, out_of, tiny_cfg

TOL = 1e-2     # documented bf16-stream Sigma tolerance (max-entry relative)


def _relerr(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.max(np.abs(a - b)) / max(np.max(np.abs(a)), 1e-30))


# ---------------------------------------------------------------------------
# kernel-level tolerance on ill-conditioned inputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scale_span", [1.0, 1e3, 1e6])
def test_gram_bf16_sigma_tolerance_ill_conditioned(scale_span):
    """Columns spanning `scale_span` in magnitude plus a common-mode offset
    — the conditioning regime where a *fp16* stream would overflow and a
    low-precision ACCUMULATOR would lose the small columns entirely. The
    bf16 stream with fp32 accumulation must stay within TOL of fp32."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    n, f = 2048, 96
    scales = jnp.logspace(0, np.log10(scale_span), f)
    x = jax.random.normal(k1, (n, f)) * scales + 0.5 * scales
    g32 = gops.gram(x, impl="ref")
    g16 = gops.gram(x.astype(jnp.bfloat16), impl="ref")
    assert _relerr(g32["s2"], g16["s2"]) <= TOL
    assert _relerr(g32["s1"], g16["s1"]) <= TOL
    # conditioning itself must survive the rounding: the bf16-stream Sigma
    # stays PSD to fp32 tolerance (eigengaps above -TOL * ||Sigma||)
    evs = np.linalg.eigvalsh(np.asarray(g16["s2"], np.float64))
    assert evs.min() > -TOL * np.abs(evs).max()


def test_gram_bf16_interpret_kernel_accumulates_fp32():
    """The Pallas kernel path (interpret mode) on a bf16 input must match
    the fp32-accumulating reference on the SAME rounded input — i.e. the
    kernel's VMEM accumulator is fp32, not bf16."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1024, 64),
                          jnp.bfloat16)
    a = gops.gram(x, impl="interpret")
    b = gops.gram(x, impl="ref")
    np.testing.assert_allclose(np.asarray(a["s2"]), np.asarray(b["s2"]),
                               rtol=1e-5, atol=1e-5)
    assert a["s2"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# tap dtype context
# ---------------------------------------------------------------------------

def test_tap_dtype_context_scopes_and_restores():
    taps = {}
    x = jnp.ones((4, 4))
    model_common.tap(taps, "a", x)
    with model_common.tap_dtype(jnp.bfloat16):
        model_common.tap(taps, "b", x)
        with model_common.tap_dtype(jnp.float32):
            model_common.tap(taps, "c", x)
        model_common.tap(taps, "d", x)
    model_common.tap(taps, "e", x)
    assert taps["a"].dtype == taps["c"].dtype == taps["e"].dtype \
        == jnp.float32
    assert taps["b"].dtype == taps["d"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# engine parity + fingerprints
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["deit-base", "granite-8b"])
def test_engine_bf16_stream_parity(arch):
    """Full pass-1 statistics under the bf16 stream stay within TOL of the
    fp32 stream for every unit (dense moments AND attention energies);
    sample counts are exact."""
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calib_factory(cfg, n=3)
    units = discover_units(cfg)
    s32 = CalibrationEngine(model, units, phase=1).run(params, calib())
    s16 = CalibrationEngine(model, units, phase=1,
                            stats_dtype="bfloat16").run(params, calib())
    for u in units:
        for key, a in s32[u.name].items():
            b = s16[u.name][key]
            if key == "n":
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            elif key == "na":
                # activity counts flip only for |x| straddling eps: allow
                # a sliver of the token count
                tol_na = 0.02 * float(np.max(np.asarray(s32[u.name]["n"])))
                assert np.max(np.abs(np.asarray(a) - np.asarray(b))) \
                    <= max(tol_na, 1.0), (u.name, key)
            else:
                assert _relerr(a, b) <= 2 * TOL, (u.name, key)


def test_engine_fingerprint_includes_stats_dtype():
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    units = discover_units(cfg)
    e32 = CalibrationEngine(model, units, phase=1)
    e16 = CalibrationEngine(model, units, phase=1, stats_dtype="bfloat16")
    assert e32.fingerprint != e16.fingerprint


# ---------------------------------------------------------------------------
# pipeline oracles
# ---------------------------------------------------------------------------

def test_zero_sparsity_oracle_under_bf16_stream():
    """corp_prune at 0/0 sparsity with stats_dtype=bfloat16: statistics are
    gathered (in bf16) but nothing is pruned, so params must pass through
    bitwise identical — the streaming dtype can never touch the weights."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    new_p, new_c, _ = corp_prune(model, params, calib_factory(cfg, n=2),
                                 PruneConfig(0.0, 0.0),
                                 stats_dtype="bfloat16")
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    y0 = out_of(model, params, batch_for(cfg))
    y1 = out_of(build_model(new_c), new_p, batch_for(cfg))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


def test_prune_under_bf16_stream_end_to_end():
    """The full 50/50 pipeline under the bf16 stream produces a working
    smaller model with finite outputs and sane compensation diagnostics."""
    cfg = tiny_cfg("deit-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    new_p, new_c, report = corp_prune(model, params, calib_factory(cfg),
                                      PruneConfig(0.5, 0.5),
                                      stats_dtype="bfloat16")
    y = out_of(build_model(new_c), new_p, batch_for(cfg))
    assert np.all(np.isfinite(np.asarray(y, np.float32)))
    for name, d in report["units"].items():
        assert np.all(np.asarray(d["j_star"]) <= np.asarray(d["j_uncomp"])
                      * (1 + 1e-3) + 1e-6), name
