"""CORP MLP compensation: closed-form identities (paper App. B.1/C.1).

These validate the *algebra* of the paper exactly — hardware-independent:
  * ridge solution matches direct least-squares on the calibration data
  * the folded layer equals the affine-compensated layer
  * distortion formula J* = tr(W_P Sigma_{P|S} W_P^T) matches the empirical
    residual (Prop C.1.1)
  * compensation gain is non-negative and matches Eq. 64 (Prop C.1.2)
  * compensation never hurts vs naive pruning (strict improvement)
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_shim import given, settings, st

from repro.core import solve as S


def make_data(rng, n, f, lowrank=None):
    if lowrank:
        basis = rng.randn(lowrank, f)
        x = rng.randn(n, lowrank) @ basis + 0.05 * rng.randn(n, f)
    else:
        x = rng.randn(n, f)
    return (x + rng.randn(f) * 0.5).astype(np.float32)


def moments(x):
    return {"n": jnp.asarray(float(x.shape[0])),
            "s1": jnp.asarray(x.sum(0)), "s2": jnp.asarray(x.T @ x)}


@pytest.mark.parametrize("f,keep_n", [(16, 8), (24, 18), (12, 3)])
def test_ridge_matches_direct_lstsq(f, keep_n):
    rng = np.random.RandomState(0)
    x = make_data(rng, 400, f, lowrank=f // 2)
    keep = jnp.arange(keep_n)
    prune = jnp.arange(keep_n, f)
    mu, sigma = S.mlp_cov(moments(x))
    lam = 1e-6
    sol = S.ridge_affine(mu, sigma, keep, prune, lam)
    # direct: centered least squares X_P ~ B X_S
    xc = x - x.mean(0)
    B_direct, *_ = np.linalg.lstsq(xc[:, :keep_n], xc[:, keep_n:],
                                   rcond=None)
    np.testing.assert_allclose(np.asarray(sol["B"]), B_direct.T, rtol=1e-2,
                               atol=1e-3)
    c_direct = x[:, keep_n:].mean(0) - B_direct.T @ x[:, :keep_n].mean(0)
    np.testing.assert_allclose(np.asarray(sol["c"]), c_direct, rtol=1e-2,
                               atol=1e-3)


def test_fold_equals_affine_compensation():
    """(W_S + W_P B) x_S + (b + W_P c) == W_S x_S + W_P (B x_S + c) + b."""
    rng = np.random.RandomState(1)
    f, d, keep_n = 20, 6, 12
    x = make_data(rng, 300, f, lowrank=8)
    w = rng.randn(f, d).astype(np.float32)    # y = h @ w
    b = rng.randn(d).astype(np.float32)
    keep, prune = jnp.arange(keep_n), jnp.arange(keep_n, f)
    mu, sigma = S.mlp_cov(moments(x))
    sol = S.ridge_affine(mu, sigma, keep, prune, 1e-6)
    w_fold = w[:keep_n] + np.asarray(sol["B"]).T @ w[keep_n:]
    b_fold = b + np.asarray(sol["c"]) @ w[keep_n:]
    xs = x[:5, :keep_n]
    xp_hat = xs @ np.asarray(sol["B"]).T + np.asarray(sol["c"])
    y_affine = xs @ w[:keep_n] + xp_hat @ w[keep_n:] + b
    y_fold = xs @ w_fold + b_fold
    np.testing.assert_allclose(y_fold, y_affine, rtol=1e-4, atol=1e-4)


def test_distortion_formula_matches_empirical():
    """Prop C.1.1: J* equals the mean squared residual on the fit data."""
    rng = np.random.RandomState(2)
    f, d, keep_n = 18, 5, 10
    x = make_data(rng, 5000, f, lowrank=9)
    w = rng.randn(f, d).astype(np.float32)
    keep, prune = jnp.arange(keep_n), jnp.arange(keep_n, f)
    mu, sigma = S.mlp_cov(moments(x))
    sol = S.ridge_affine(mu, sigma, keep, prune, 1e-8)
    diag = S.mlp_distortion(sol, jnp.asarray(w[keep_n:]))
    xp_hat = x[:, :keep_n] @ np.asarray(sol["B"]).T + np.asarray(sol["c"])
    resid = (x[:, keep_n:] - xp_hat) @ w[keep_n:]
    emp = float(np.mean(np.sum(resid ** 2, -1)))
    assert float(diag["j_star"]) == pytest.approx(emp, rel=2e-2)
    # uncompensated: residual = W_P x_P
    emp_un = float(np.mean(np.sum((x[:, keep_n:] @ w[keep_n:]) ** 2, -1)))
    assert float(diag["j_uncomp"]) == pytest.approx(emp_un, rel=2e-2)


@settings(max_examples=20, deadline=None)
@given(f=st.integers(6, 24), frac=st.floats(0.2, 0.8),
       seed=st.integers(0, 10_000), lowrank=st.booleans())
def test_gain_nonnegative_property(f, frac, seed, lowrank):
    """Prop C.1.2: compensation gain >= 0 for ANY data/split (hypothesis)."""
    rng = np.random.RandomState(seed)
    keep_n = max(1, min(f - 1, int(f * frac)))
    x = make_data(rng, 200, f, lowrank=max(2, f // 2) if lowrank else None)
    perm = rng.permutation(f)
    keep = jnp.asarray(np.sort(perm[:keep_n]))
    prune = jnp.asarray(np.sort(perm[keep_n:]))
    w = rng.randn(f, 4).astype(np.float32)
    mu, sigma = S.mlp_cov(moments(x))
    sol = S.ridge_affine(mu, sigma, keep, prune, 1e-6)
    diag = S.mlp_distortion(sol, jnp.asarray(np.asarray(w)[np.asarray(prune)]))
    gain = float(diag["gain"])
    assert gain >= -1e-3 * max(1.0, abs(float(diag["j_uncomp"])))
    assert float(diag["j_star"]) <= float(diag["j_uncomp"]) * (1 + 1e-5)


@settings(max_examples=15, deadline=None)
@given(f=st.integers(6, 20), frac=st.floats(0.25, 0.75),
       seed=st.integers(0, 5000))
def test_ridge_satisfies_normal_equations(f, frac, seed):
    """(B, c) solve the ridge normal equations exactly:
    B (Sigma_SS + lam I) = Sigma_PS  and  c = mu_P - B mu_S."""
    rng = np.random.RandomState(seed)
    keep_n = max(1, min(f - 1, int(f * frac)))
    x = make_data(rng, 300, f)
    keep = jnp.arange(keep_n)
    prune = jnp.arange(keep_n, f)
    mu, sigma = S.mlp_cov(moments(x))
    lam = 1e-3 * float(jnp.mean(jnp.diag(sigma)))
    sol = S.ridge_affine(mu, sigma, keep, prune, lam)
    B = np.asarray(sol["B"], np.float64)
    S_SS = np.asarray(sigma, np.float64)[:keep_n, :keep_n]
    S_PS = np.asarray(sigma, np.float64)[keep_n:, :keep_n]
    lhs = B @ (S_SS + lam * np.eye(keep_n))
    scale = max(1.0, float(np.abs(S_PS).max()))
    np.testing.assert_allclose(lhs, S_PS, rtol=2e-3, atol=2e-3 * scale)
    c_expect = np.asarray(mu)[keep_n:] - B @ np.asarray(mu)[:keep_n]
    np.testing.assert_allclose(np.asarray(sol["c"]), c_expect, rtol=2e-3,
                               atol=2e-3)


def test_large_lam_drives_B_to_zero_c_to_mean():
    """lam -> inf kills the linear term: B -> 0 and c -> mu_P (the
    compensator degenerates to mean imputation)."""
    rng = np.random.RandomState(7)
    f, keep_n = 16, 10
    x = make_data(rng, 400, f, lowrank=8)
    keep, prune = jnp.arange(keep_n), jnp.arange(keep_n, f)
    mu, sigma = S.mlp_cov(moments(x))
    sol = S.ridge_affine(mu, sigma, keep, prune, 1e9)
    assert float(jnp.max(jnp.abs(sol["B"]))) < 1e-6
    np.testing.assert_allclose(np.asarray(sol["c"]),
                               np.asarray(mu)[keep_n:], rtol=1e-4,
                               atol=1e-5)
    # and the gain collapses accordingly: j_star ~ j_uncomp at B=0, c=mu_P
    # is NOT guaranteed (mean subtraction still helps), but gain >= 0 must
    # survive even in the degenerate limit
    w = rng.randn(f - keep_n, 3).astype(np.float32)
    diag = S.mlp_distortion(sol, jnp.asarray(w))
    assert float(diag["gain"]) >= -1e-3


def test_lossfree_when_linearly_dependent():
    """Pruned channels exactly predictable -> J* ~ 0 (paper: 'loss-free
    iff W_P Sigma_{P|S}^{1/2} = 0')."""
    rng = np.random.RandomState(3)
    f, keep_n = 12, 8
    xs = rng.randn(1000, keep_n).astype(np.float32)
    A = rng.randn(keep_n, f - keep_n).astype(np.float32)
    x = np.concatenate([xs, xs @ A + 1.5], axis=1)
    keep, prune = jnp.arange(keep_n), jnp.arange(keep_n, f)
    mu, sigma = S.mlp_cov(moments(x))
    sol = S.ridge_affine(mu, sigma, keep, prune, 1e-8)
    w = rng.randn(f - keep_n, 4).astype(np.float32)
    diag = S.mlp_distortion(sol, jnp.asarray(w))
    assert float(diag["j_star"]) < 1e-3 * float(diag["j_uncomp"])
