"""Distribution tests on an 8-device host mesh (subprocess: the device count
must be set before jax initializes, and the main pytest process keeps 1
device per the assignment)."""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_py(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    # force the host platform: with JAX_PLATFORMS unset, jax probes the TPU
    # backend first, and on TPU-shaped containers without TPU metadata the
    # libtpu GCP metadata fetch retries for ~7 minutes per subprocess before
    # falling back to CPU (the host-device-count flag only applies to CPU).
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_pjit_train_step_matches_single_device():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.distrib import sharding as S
from repro.launch.mesh import make_mesh
from repro.optim import AdamWConfig, adamw_init, adamw_update

cfg = reduced(get_config('granite-8b')).replace(dtype='float32', d_model=64)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
ocfg = AdamWConfig()
opt = adamw_init(params, ocfg)
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1),(8,16),0,cfg.vocab_size),
         'labels': jax.random.randint(jax.random.PRNGKey(2),(8,16),0,cfg.vocab_size)}

def step(p, o, b):
    loss, g = jax.value_and_grad(lambda pp: model.loss(pp, b))(p)
    np_, no_, _ = adamw_update(p, g, o, 1e-3, ocfg)
    return np_, no_, loss

# single device reference
p1, o1, l1 = jax.jit(step)(params, opt, batch)

mesh = make_mesh((2, 4))
pspec = S.param_specs(params, mesh)
pshard = S.shardings_of(pspec, mesh)
oshard = S.shardings_of(S.param_specs(opt, mesh), mesh)
bshard = S.shardings_of(S.batch_specs(batch, mesh), mesh)
with mesh:
    jstep = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                    out_shardings=(pshard, oshard, None))
    p2, o2, l2 = jstep(jax.device_put(params, pshard),
                       jax.device_put(opt, oshard),
                       jax.device_put(batch, bshard))
print('loss_diff', abs(float(l1) - float(l2)))
d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(p1), jax.tree.leaves(jax.device_get(p2))))
print('param_diff', d)
assert abs(float(l1) - float(l2)) < 1e-4
assert d < 1e-4
print('OK')
""")
    assert "OK" in out


def test_distributed_corp_matches_single_device():
    """CORP statistics under a (2,4) mesh == single-device statistics:
    the psum-reduced pipeline must produce identical pruned weights."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.core import corp_prune, PruneConfig
from repro.launch.mesh import make_mesh

cfg = reduced(get_config('deit-base')).replace(dtype='float32')
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
def calib():
    for i in range(2):
        yield {'images': jax.random.normal(jax.random.PRNGKey(i), (8, cfg.img_size, cfg.img_size, 3))}
pc = PruneConfig(0.5, 0.5)
p_single, c_single, _ = corp_prune(model, params, calib, pc)
mesh = make_mesh((2, 4))
with mesh:
    p_mesh, c_mesh, _ = corp_prune(model, params, calib, pc)
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p_single), jax.tree.leaves(jax.device_get(p_mesh))))
print('max diff', d)
assert d < 1e-3
print('OK')
""")
    assert "OK" in out


@pytest.mark.subprocess
def test_mini_dryrun_multipod_axes():
    """A (2,2,2) pod/data/model mesh must lower+compile a reduced train step
    (proves the 'pod' axis shards end-to-end)."""
    out = run_py("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.distrib import sharding as S
from repro.launch.mesh import make_mesh
from repro.optim import AdamWConfig, adamw_init, adamw_update

cfg = reduced(get_config('qwen3-moe-235b-a22b')).replace(dtype='float32')
model = build_model(cfg)
params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
ocfg = AdamWConfig()
opt_sds = jax.eval_shape(lambda: adamw_init(params_sds, ocfg))
mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
pshard = S.shardings_of(S.param_specs(params_sds, mesh, fsdp=True), mesh)
oshard = S.shardings_of(S.param_specs(opt_sds, mesh, fsdp=True), mesh)
batch = {'tokens': jax.ShapeDtypeStruct((8, 32), jnp.int32),
         'labels': jax.ShapeDtypeStruct((8, 32), jnp.int32)}

def step(p, o, b):
    loss, g = jax.value_and_grad(lambda pp: model.loss(pp, b))(p)
    np_, no_, _ = adamw_update(p, g, o, 1e-3, ocfg)
    return np_, no_, loss

with mesh:
    lowered = jax.jit(step, in_shardings=(pshard, oshard, None),
                      out_shardings=(pshard, oshard, None)).lower(
        params_sds, opt_sds, batch)
    compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, list):   # older jax returns [dict] per computation
    ca = ca[0] if ca else {}
print('flops', ca.get('flops'))
print('OK')
""")
    assert "OK" in out
