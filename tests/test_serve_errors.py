"""Guards for the single-source-of-truth rejection table (serve/errors.py).

Two invariants keep the engine's refusal text and the test suite's
expectations from drifting apart:

1. every template formats cleanly (no stale placeholders, no collisions),
   and ``msg`` refuses unknown keys and missing placeholders loudly;
2. no other test file re-inlines a table message as a string literal on a
   ``pytest.raises(match=...)`` or ``xfail(reason=...)`` line — those must
   be BUILT from ``errors.msg`` so renaming an entry updates both sides.

The scan keys on each template's longest literal fragment (placeholders
stripped), so prose in docstrings/comments stays free to *describe* the
refusals; only assertion lines are constrained.
"""
from __future__ import annotations

import pathlib
import string

import pytest

from repro.serve import errors

_FMT = string.Formatter()


def _placeholders(template):
    return [f for _, f, _, _ in _FMT.parse(template) if f is not None]


def _dummy_kwargs(template):
    # ints satisfy both {x} and {x!r}/{x:d}-style fields
    return {f.split("!")[0].split(":")[0].split(".")[0].split("[")[0]: 7
            for f in _placeholders(template)}


def _literal_fragments(template):
    return [lit for lit, _, _, _ in _FMT.parse(template) if lit]


def test_every_template_formats_cleanly():
    seen = set()
    for key, template in errors.ERRORS.items():
        m = errors.msg(key, **_dummy_kwargs(template))
        assert m and not m.isspace(), key
        assert "{" not in m and "}" not in m, f"{key}: stale placeholder"
        assert m not in seen, f"{key}: collides with another entry"
        seen.add(m)


def test_msg_raises_on_unknown_key_and_stale_placeholder():
    with pytest.raises(KeyError):
        errors.msg("definitely_not_a_refusal")
    # a call site that forgets a placeholder must fail loudly, not emit
    # a half-formatted message
    keyed = [k for k, t in errors.ERRORS.items() if _placeholders(t)]
    assert keyed, "table unexpectedly placeholder-free"
    with pytest.raises((KeyError, IndexError)):
        errors.msg(keyed[0])


def test_no_test_file_reinlines_a_table_message():
    """The drift guard: the longest literal fragment of every template
    (>= 12 chars, so generic words like 'slot' don't trip it) must not
    appear on any ``match=`` / ``reason=`` line of another test file."""
    fragments = {}
    for key, template in errors.ERRORS.items():
        lits = [f for f in _literal_fragments(template)
                if len(f.strip()) >= 12]
        if lits:
            fragments[key] = max(lits, key=len)
    assert len(fragments) >= 10      # the table is substantially guarded
    # the scheduler's chunked-prefill refusals are among the guarded set
    assert {"chunk_invalid", "chunk_unsupported",
            "continue_without_begin"} <= set(fragments)
    here = pathlib.Path(__file__)
    offenders = []
    for path in sorted(here.parent.glob("*.py")):
        if path == here:
            continue
        for ln, line in enumerate(path.read_text().splitlines(), 1):
            if "match=" not in line and "reason=" not in line:
                continue
            for key, frag in fragments.items():
                if frag in line:
                    offenders.append(f"{path.name}:{ln} inlines "
                                     f"{key!r} ({frag!r})")
    assert not offenders, "\n".join(
        ["build these from repro.serve.errors.msg instead:"] + offenders)


def test_table_is_the_only_message_source_in_serve():
    """No serve module (besides errors.py itself) may carry a table
    message as a literal — every raise goes through ``errors.msg``."""
    fragments = {k: max((f for f in _literal_fragments(t)
                         if len(f.strip()) >= 12), key=len, default=None)
                 for k, t in errors.ERRORS.items()}
    src = pathlib.Path(errors.__file__).parent
    scanned = sorted(src.glob("*.py"))
    # the scheduler layer raises chunk refusals: it MUST be in the scan
    assert "scheduler.py" in {p.name for p in scanned}
    offenders = []
    for path in scanned:
        if path.name == "errors.py":
            continue
        text = path.read_text()
        for key, frag in fragments.items():
            if frag and frag in text:
                offenders.append(f"{path.name} inlines {key!r}")
    assert not offenders, offenders
