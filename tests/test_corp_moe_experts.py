"""CORP whole-expert pruning for MoE blocks (beyond-paper Eq. 9 extension).

The regression vector is ``z_t = [x_t, c_t1..c_tE]`` — the MoE block input
concatenated with the gate-weighted per-expert contributions — and the
removed experts' contribution blocks are ridge-regressed onto the *input*
block (x is routing-invariant; the retained contributions shift when the
router renormalizes gate mass onto survivors, so a fit against them is a
fit against the wrong distribution). Mirrors ``test_corp_mlp.py``:

  * ridge normal equations hold exactly on the expert-block index split
  * pruning 0 experts is the bitwise identity AND serves token-identical
  * 50%-expert e2e: compensation within parity tolerance of (and with
    layer-local j_star <= j_uncomp vs) naive expert dropping
  * expert-pruned models serve through the engine token-identical to
    their own full-sequence greedy forward
  * streamed pruning reproduces the one-shot expert fold byte-for-byte
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import PruneConfig, corp_prune
from repro.core import solve as S
from repro.core.ranking import expert_scores, rank_experts
from repro.models import build_model

from helpers import (batch_for, calib_factory, greedy_chain_ok, mse,
                     out_of, tiny_cfg)

MOE_ARCHS = ["qwen3-moe-235b-a22b", "deepseek-v3-671b"]


def _moments(z):
    return {"n": jnp.asarray(float(z.shape[0])),
            "s1": jnp.asarray(z.sum(0)), "s2": jnp.asarray(z.T @ z)}


def _expert_blocks(rng, n, e_num, d):
    """Synthetic z = [x | c_1..c_E]: contributions correlated with the
    input (each expert is roughly a linear map of x), as in a real block."""
    x = rng.randn(n, d).astype(np.float32)
    cs = [x @ rng.randn(d, d).astype(np.float32) * 0.5
          + 0.1 * rng.randn(n, d).astype(np.float32)
          for _ in range(e_num)]
    return np.concatenate([x] + cs, axis=1)


# ---------------------------------------------------------------------------
# the algebra: ridge on the (input | contributions) block split
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(e_num=st.integers(2, 5), d=st.integers(2, 6),
       seed=st.integers(0, 5000))
def test_expert_ridge_satisfies_normal_equations(e_num, d, seed):
    """(B, c) for removed-expert blocks regressed on the input block solve
    the ridge normal equations exactly — the same index split
    ``_fold_moe_experts`` builds (keep = block 0, prune = removed
    experts' blocks)."""
    rng = np.random.RandomState(seed)
    z = _expert_blocks(rng, 400, e_num, d)
    n_rm = rng.randint(1, e_num)            # remove the LAST n_rm experts
    keep = jnp.arange(d)                    # input block
    prune = jnp.arange((e_num + 1 - n_rm) * d, (e_num + 1) * d)
    mu, sigma = S.mlp_cov(_moments(z))
    lam = 1e-3 * float(jnp.mean(jnp.diag(sigma)))
    sol = S.ridge_affine(mu, sigma, keep, prune, lam)
    B = np.asarray(sol["B"], np.float64)
    sig = np.asarray(sigma, np.float64)
    ks, ps = np.asarray(keep), np.asarray(prune)
    lhs = B @ (sig[np.ix_(ks, ks)] + lam * np.eye(d))
    rhs = sig[np.ix_(ps, ks)]
    scale = max(1.0, float(np.abs(rhs).max()))
    np.testing.assert_allclose(lhs, rhs, rtol=2e-3, atol=2e-3 * scale)
    c_expect = np.asarray(mu)[ps] - B @ np.asarray(mu)[ks]
    np.testing.assert_allclose(np.asarray(sol["c"]), c_expect, rtol=2e-3,
                               atol=2e-3)
    # contributions enter the output through identity: distortion with
    # stacked-identity w_P can only improve over dropping the blocks
    w_p = jnp.tile(jnp.eye(d, dtype=jnp.float32), (n_rm, 1))
    diag = S.mlp_distortion(sol, w_p)
    assert float(diag["j_star"]) <= float(diag["j_uncomp"]) * (1 + 1e-5)


def test_expert_scores_rank_contribution_energy():
    """expert_scores is the per-expert second-moment energy of its
    gate-weighted contribution (input block 0 skipped); rank_experts
    keeps the highest-energy experts."""
    rng = np.random.RandomState(3)
    e_num, d = 4, 5
    z = _expert_blocks(rng, 300, e_num, d)
    z[:, d * 2: d * 3] *= 10.0              # expert 1 dominates
    z[:, d * 4: d * 5] *= 0.01              # expert 3 negligible
    stats = {"yn": np.float32(z.shape[0]),
             "ys1": z.sum(0), "ys2": z.T @ z,
             "n": np.ones((e_num,), np.float32)}   # only shape[-1] is read
    sc = expert_scores(stats)
    assert sc.shape == (e_num,)
    assert np.argmax(sc) == 1 and np.argmin(sc) == 3
    keep, prune = rank_experts(stats, 2)
    assert 1 in keep.tolist() and 3 in prune.tolist()
    assert sorted(keep.tolist() + prune.tolist()) == list(range(e_num))


# ---------------------------------------------------------------------------
# zero-expert-sparsity oracle: bitwise identity, token-identical serving
# ---------------------------------------------------------------------------

def test_zero_expert_sparsity_bitwise_and_serving_identity():
    from repro.serve import ServeEngine, synthetic_trace
    cfg = tiny_cfg("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    new_p, new_c, _ = corp_prune(model, params, calib_factory(cfg),
                                 PruneConfig(0.0, 0.0, expert_sparsity=0.0))
    assert new_c.experts_kept is None
    assert new_c.eff_num_experts == cfg.moe.num_experts
    batch = batch_for(cfg)
    np.testing.assert_array_equal(
        np.asarray(out_of(model, params, batch)),
        np.asarray(out_of(build_model(new_c), new_p, batch)))
    trace = synthetic_trace(4, cfg.vocab_size, seed=11,
                            prompt_range=(4, 10), gen_range=(2, 5))
    dense = ServeEngine(model, params, n_slots=2, max_len=24).run(trace)
    served = ServeEngine(build_model(new_c), new_p,
                         n_slots=2, max_len=24).run(trace)
    for a, b in zip(dense, served):
        assert list(a.tokens) == list(b.tokens)


# ---------------------------------------------------------------------------
# 50%-expert e2e: comp vs uncomp, config bookkeeping, param shrinkage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_expert_prune_end_to_end(arch):
    cfg = tiny_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calib_factory(cfg)
    batch = batch_for(cfg, B=2, T=24, seed=77)
    y0 = out_of(model, params, batch)

    errs = {}
    for comp in (True, False):
        pc = PruneConfig(0.0, 0.0, expert_sparsity=0.5, compensate=comp)
        new_p, new_c, report = corp_prune(model, params, calib, pc)
        assert new_c.experts_kept == max(
            cfg.moe.top_k, cfg.moe.num_experts // 2)
        assert new_c.eff_num_experts < cfg.moe.num_experts
        y1 = out_of(build_model(new_c), new_p, batch)
        assert np.all(np.isfinite(np.asarray(y1, np.float32)))
        errs[comp] = mse(y1, y0)
        n0 = sum(x.size for x in jax.tree.leaves(params))
        n1 = sum(x.size for x in jax.tree.leaves(new_p))
        assert n1 < n0
        ex_units = {k: d for k, d in report["units"].items()
                    if k.endswith("/experts")}
        assert ex_units, "no expert fold reported"
        if comp:
            # layer-local guarantee: ridge never loses to naive dropping
            for name, d in ex_units.items():
                assert np.all(np.asarray(d["j_star"]) <= np.asarray(
                    d["j_uncomp"]) * (1 + 1e-3) + 1e-6), name
    # parity tolerance mirrors test_prune_pipeline: the guarantee is
    # layer-local; e2e error through renormalized routing may wobble
    assert errs[True] <= errs[False] * 1.25, \
        f"expert compensation should not hurt: {errs}"


def test_combined_channel_and_expert_prune_runs():
    """Hidden-channel fold (paper Eq. 9) and whole-expert fold compose:
    both reductions land in one corp_prune call and the model still runs
    finite with both dims shrunk."""
    cfg = tiny_cfg("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    new_p, new_c, report = corp_prune(
        model, params, calib_factory(cfg),
        PruneConfig(0.5, 0.5, expert_sparsity=0.5))
    assert new_c.d_ff_kept is not None and new_c.qk_kept is not None
    assert new_c.experts_kept == cfg.moe.top_k
    y = out_of(build_model(new_c), new_p, batch_for(cfg))
    assert np.all(np.isfinite(np.asarray(y, np.float32)))
    assert any(k.endswith("/experts") for k in report["plan_sizes"])


# ---------------------------------------------------------------------------
# serving parity: expert-pruned engine == its own full greedy forward
# ---------------------------------------------------------------------------

def test_expert_pruned_serving_parity():
    from repro.serve import ServeEngine
    from repro.serve.engine import Request
    cfg = tiny_cfg("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    new_p, new_c, _ = corp_prune(model, params, calib_factory(cfg),
                                 PruneConfig(0.0, 0.0, expert_sparsity=0.5))
    pm = build_model(new_c)
    rng = np.random.RandomState(5)
    reqs = [Request(rid=i, tokens=rng.randint(
        0, cfg.vocab_size, size=p).astype(np.int32), gen=g)
        for i, (p, g) in enumerate([(5, 3), (9, 4), (4, 2), (7, 3)])]
    eng = ServeEngine(pm, new_p, n_slots=2, max_len=24)
    comps = eng.run(reqs)
    for req, c in zip(reqs, comps):
        assert len(c.tokens) == req.gen
        assert greedy_chain_ok(pm, new_p, req, c.tokens), req.rid


# ---------------------------------------------------------------------------
# streamed == one-shot (statistics are linear; partitioning is exact)
# ---------------------------------------------------------------------------

def test_streamed_expert_prune_matches_full():
    from repro.core.pruner import corp_prune_streamed
    cfg = tiny_cfg("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    calib = calib_factory(cfg, n=3)
    pc = PruneConfig(0.0, 0.0, expert_sparsity=0.5)
    p_full, c_full, _ = corp_prune(model, params, calib, pc)
    p_str, c_str, rep = corp_prune_streamed(model, params, calib, pc,
                                            unit_group_size=1)
    assert c_full == c_str
    assert rep["groups"] > 1
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_str)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-6)
