"""Shared test fixtures/utilities."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced


def tiny_cfg(arch: str, **kw):
    cfg = reduced(get_config(arch)).replace(dtype="float32", **kw)
    if cfg.moe is not None:
        # avoid capacity drops so algebraic identities hold exactly
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


def batch_for(cfg, B=2, T=16, seed=0):
    k = jax.random.PRNGKey(seed)
    if cfg.family == "vit":
        return {"images": jax.random.normal(
            k, (B, cfg.img_size, cfg.img_size, 3)),
            "labels": jnp.zeros((B,), jnp.int32)}
    b = {"tokens": jax.random.randint(k, (B, T), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.fold_in(k, 1), (B, T), 0,
                                      cfg.vocab_size)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(jax.random.fold_in(k, 2),
                                        (B, T, cfg.d_model))
    if cfg.frontend == "patch_stub":
        b["patch_embeds"] = jax.random.normal(jax.random.fold_in(k, 3),
                                              (B, 4, cfg.d_model))
    return b


def calib_factory(cfg, n=4, B=4, T=24, seed=100):
    def make():
        for i in range(n):
            b = batch_for(cfg, B=B, T=T, seed=seed + i)
            b.pop("labels", None)
            yield b
    return make


def out_of(model, params, batch):
    y = model.apply(params, batch)
    return y[0] if isinstance(y, tuple) else y


def mse(a, b):
    return float(jnp.mean(jnp.square((a - b).astype(jnp.float32))))
