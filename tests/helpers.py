"""Shared test fixtures/utilities."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced


def tiny_cfg(arch: str, **kw):
    cfg = reduced(get_config(arch)).replace(dtype="float32", **kw)
    if cfg.moe is not None:
        # avoid capacity drops so algebraic identities hold exactly
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


def batch_for(cfg, B=2, T=16, seed=0):
    k = jax.random.PRNGKey(seed)
    if cfg.family == "vit":
        return {"images": jax.random.normal(
            k, (B, cfg.img_size, cfg.img_size, 3)),
            "labels": jnp.zeros((B,), jnp.int32)}
    b = {"tokens": jax.random.randint(k, (B, T), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.fold_in(k, 1), (B, T), 0,
                                      cfg.vocab_size)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(jax.random.fold_in(k, 2),
                                        (B, T, cfg.d_model))
    if cfg.frontend == "patch_stub":
        b["patch_embeds"] = jax.random.normal(jax.random.fold_in(k, 3),
                                              (B, 4, cfg.d_model))
    return b


def calib_factory(cfg, n=4, B=4, T=24, seed=100):
    def make():
        for i in range(n):
            b = batch_for(cfg, B=B, T=T, seed=seed + i)
            b.pop("labels", None)
            yield b
    return make


def out_of(model, params, batch):
    y = model.apply(params, batch)
    return y[0] if isinstance(y, tuple) else y


def greedy_chain_ok(model, params, req, out_tokens):
    """Greedy self-consistency via ONE full forward: feed prompt + generated
    tokens, and every generated token must equal the argmax at the position
    that produced it (causality makes this equivalent to a stepwise
    rollout). ``req`` is a serve Request (``frames`` ride along for
    enc-dec)."""
    cfg = model.cfg
    P = len(req.tokens)
    seq = np.concatenate([np.asarray(req.tokens, np.int32),
                          np.asarray(out_tokens[:-1], np.int32)])
    batch = {"tokens": jnp.asarray(seq)[None]}
    if getattr(req, "frames", None) is not None:
        batch["frames"] = jnp.asarray(req.frames)[None]
    logits = model.apply(params, batch)[0]
    pred = np.asarray(jnp.argmax(logits[0, :, : cfg.vocab_size], axis=-1))
    want = pred[P - 1: P - 1 + len(out_tokens)]
    return list(want) == [int(t) for t in out_tokens]


def mse(a, b):
    return float(jnp.mean(jnp.square((a - b).astype(jnp.float32))))
