"""``hypothesis`` with a vendored fallback so the suite always collects.

The property tests use a small surface of hypothesis: ``@settings`` /
``@given`` with keyword strategies drawn from ``integers``, ``floats``,
``booleans`` and ``sampled_from``. When the real library is installed
(``pip install -r requirements-dev.txt``) it is used unchanged — shrinking,
the example database, and the full strategy engine included. When it is
missing (e.g. a minimal CI or laptop env), this module degrades to a
deterministic sampler: each test runs ``max_examples`` pseudo-random
examples from a seed derived from the test name, and a failure reports the
falsifying example. Import as::

    from hypothesis_shim import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import random
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

    st = _Strategies()

    def settings(max_examples: int = 10, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def runner():
                n = getattr(runner, "_shim_max_examples", 10)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    ex = {k: s.draw(rng)
                          for k, s in sorted(strategies.items())}
                    try:
                        fn(**ex)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example {ex!r}: {e}") from e
            # plain zero-arg function (no functools.wraps: pytest must not
            # unwrap to the parametrized signature and hunt for fixtures)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
