"""Gram-kernel tile autotuner: validity, caching, monotonicity vs the fixed
legacy tiles, and end-to-end dispatch with autotuned tiles."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gram import autotune
from repro.kernels.gram import ops as gops
from repro.kernels.gram import ref as gref

# a grid of (N, F) covering square, tall, wide, tiny and the zero-padded
# ragged cases the kernel supports via padding
SHAPE_GRID = [(128, 128), (512, 256), (4096, 192), (25088, 1280),
              (16384, 3072), (300, 100), (257, 129), (100, 300),
              (8192, 12800), (7, 3)]


@pytest.mark.parametrize("n,f", SHAPE_GRID)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_choices_valid(n, f, dtype):
    """Every choice respects TPU tiling (lane 128 / dtype sublane), the
    VMEM budget, and is drawn from the candidate grid."""
    bf, bn = autotune.choose_tiles(n, f, dtype)
    sub = 16 if dtype == "bfloat16" else 8
    assert bf % 128 == 0
    assert bn % sub == 0
    assert bf in autotune.BF_CANDIDATES and bn in autotune.BN_CANDIDATES
    assert autotune.vmem_bytes(bf, bn, dtype) <= autotune.DEFAULT_VMEM_BUDGET


@pytest.mark.parametrize("n,f", SHAPE_GRID)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_never_predicted_slower_than_fixed_defaults(n, f, dtype):
    """(128, 512) is in the candidate set, so the argmin choice can never
    be predicted slower — the bench_calibration.py gate in miniature."""
    bf, bn = autotune.choose_tiles(n, f, dtype)
    assert autotune.predicted_time(n, f, dtype, bf, bn) <= \
        autotune.predicted_time(n, f, dtype, 128, 512)


def test_choice_cached_per_shape():
    a = autotune.choose_tiles(4096, 768)
    assert autotune.choose_tiles(4096, 768) is a          # lru_cache hit
    assert autotune.choose_tiles(4096, 768, "bfloat16") is not a


def test_vmem_budget_binds():
    """A tight budget must push the choice to smaller tiles, never crash."""
    bf, bn = autotune.choose_tiles(65536, 8192, vmem_budget=2 * 2 ** 20)
    assert autotune.vmem_bytes(bf, bn) <= 2 * 2 ** 20
    big = autotune.choose_tiles(65536, 8192)
    assert autotune.vmem_bytes(*big) > autotune.vmem_bytes(bf, bn)


def test_bf16_streams_deeper_token_tiles():
    """Half the itemsize -> the same VMEM budget holds deeper token tiles
    on large shapes (the bf16-streaming/autotune composition)."""
    bf32, bn32 = autotune.choose_tiles(16384, 3072, "float32")
    bf16, bn16 = autotune.choose_tiles(16384, 3072, "bfloat16")
    assert bn16 >= bn32


@pytest.mark.parametrize("n,f", [(300, 100), (257, 129), (512, 192)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_autotuned_tiles_match_ref(n, f, dtype):
    """bf=bn=None -> autotuned tiles; interpret-mode kernel must still
    match the oracle on ragged shapes in both streaming dtypes."""
    x = jax.random.normal(jax.random.PRNGKey(0), (n, f), dtype)
    a = gops.gram(x, impl="interpret")
    b = gref.gram(x)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(a["s2"]), np.asarray(b["s2"]),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(a["s1"]), np.asarray(b["s1"]),
                               rtol=tol, atol=tol)


def test_gram_tiles_env_pin(monkeypatch):
    """REPRO_GRAM_TILES pins the tiles globally (the --gram-tiles CLI
    knob); explicit args still win over the env."""
    seen = {}
    import repro.kernels.gram.ops as ops_mod

    def fake_pallas(x, *, bf, bn, interpret):
        seen["tiles"] = (bf, bn)
        return gref.gram(x)

    monkeypatch.setattr(ops_mod, "_pallas_gram", fake_pallas)
    monkeypatch.setenv("REPRO_GRAM_TILES", "256,1024")
    x = jnp.ones((64, 32))
    gops.gram(x, impl="interpret")
    assert seen["tiles"] == (256, 1024)
    gops.gram(x, impl="interpret", bf=128, bn=512)
    assert seen["tiles"] == (128, 512)


def test_tuning_table_rows():
    rows = autotune.tuning_table()
    assert len(rows) == len(autotune.DEFAULT_SHAPES) * 2
    for r in rows:
        assert r["t_pred"] <= r["t_fixed"]
        assert r["speedup"] >= 1.0
