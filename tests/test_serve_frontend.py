"""Front-end over the *real* engine: deadline edge cases, streaming,
prefix-cache exactness, asyncio interleaving, and byte identity with the
engine's own trace runner.

Deadline decisions all flow through the front-end's injectable clock, so a
manual clock makes every expiry boundary deterministic even with a real
jitted model underneath. The four edge cases ISSUE 6 names each get a
test: expiry exactly at the admit boundary, during prefill, at the final
decode step, and while queued — each asserting the partial-token count
and that the freed slot is refilled.
"""
from __future__ import annotations

import asyncio
import re

import jax
import numpy as np
import pytest

from helpers import tiny_cfg
from repro.models import build_model
from repro.serve import (AsyncServeFrontend, Overloaded, PrefixCache,
                         ServeEngine, ServeFrontend, Status, errors,
                         frontend_table, synthetic_trace)
from repro.serve.engine import Request
from repro.serve.testing import FleetFakeEngine


@pytest.fixture(scope="module")
def lm():
    cfg = tiny_cfg("qwen2-1.5b")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _engine(lm, n_slots=2, max_len=48):
    model, params = lm
    return ServeEngine(model, params, n_slots=n_slots, max_len=max_len)


class ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _req(rid, plen, gen, deadline=None):
    return Request(rid=rid, tokens=(np.arange(plen) % 7 + 1 + rid)
                   .astype(np.int32), gen=gen, deadline=deadline)


def _prefills(eng):
    return sum(v for k, v in eng.stats.items() if k.startswith("prefill"))


# ---------------------------------------------------------------------------
# byte identity with the engine's own runner (acceptance criterion c)
# ---------------------------------------------------------------------------

def test_frontend_matches_engine_run_byte_identical(lm):
    """No deadlines, no prefix cache: the front-end's token streams must be
    byte-identical to ``ServeEngine.run`` on the same trace."""
    model, params = lm
    trace = synthetic_trace(n=6, seed=3, rate=50.0, prompt_range=(4, 10),
                            gen_range=(2, 6), vocab=model.cfg.vocab_size)
    eng_a = ServeEngine(model, params, n_slots=2, max_len=48)
    done = eng_a.run(trace)
    eng_b = ServeEngine(model, params, n_slots=2, max_len=48)
    handles = ServeFrontend(eng_b, queue_depth=8).run(trace)
    assert all(h.status is Status.DONE for h in handles)
    for h in handles:
        assert h.tokens == [int(t) for t in done[h.rid].tokens], \
            f"rid {h.rid}: stream diverged from engine.run"


# ---------------------------------------------------------------------------
# deadline edge cases (manual clock, real engine)
# ---------------------------------------------------------------------------

def test_deadline_expired_exactly_at_admit(lm):
    """deadline == clock at the admit boundary: expired *before* prefill —
    zero tokens, zero engine work, slot still admits the next request."""
    eng = _engine(lm, n_slots=1)
    clk = ManualClock()
    fe = ServeFrontend(eng, queue_depth=4, clock=clk)
    h = fe.submit(_req(0, 4, 5, deadline=0.0))    # dead on arrival
    assert h.status is Status.EXPIRED and h.tokens == []
    assert _prefills(eng) == 0 and eng.active_count() == 0
    g = fe.submit(_req(1, 4, 2))                  # slot was never consumed
    while not g.finished:
        fe.step()
    assert g.status is Status.DONE and len(g.tokens) == 2


def test_deadline_expired_during_prefill(lm):
    """Deadline passes while prefill runs: the prefill token is kept, the
    request expires with exactly 1 token, and the slot is refilled."""
    eng = _engine(lm, n_slots=1)
    clk = ManualClock()
    fe = ServeFrontend(eng, queue_depth=4, clock=clk)
    real_admit = eng.admit

    def slow_admit(req, slot, prefix_cache=None):
        real_admit(req, slot, prefix_cache=prefix_cache)
        clk.advance(10.0)                         # prefill "took" 10s

    eng.admit = slow_admit
    h = fe.submit(_req(0, 4, 6, deadline=5.0))
    assert h.status is Status.EXPIRED
    assert len(h.tokens) == 1                     # the prefill token only
    assert eng.stats["cancels"] == 1
    assert eng.active_count() == 0                # slot freed mid-flight
    g = fe.submit(_req(1, 4, 2))
    while not g.finished:
        fe.step()
    assert g.status is Status.DONE


def test_deadline_at_final_decode_step_completion_wins(lm):
    """Tie-break: a deadline passing *during* the final decode step loses
    to completion (the tokens exist); one step earlier it expires with
    partial tokens."""
    eng = _engine(lm, n_slots=1)
    clk = ManualClock()
    fe = ServeFrontend(eng, queue_depth=4, clock=clk)
    real_decode = eng.decode_step

    def timed_decode():
        out = real_decode()
        clk.advance(1.0)                          # each decode step = 1s
        return out

    eng.decode_step = timed_decode
    # gen=3: prefill tok@t=0, decode steps end at t=1 (tok2) and t=2 (tok3)
    h = fe.submit(_req(0, 4, 3, deadline=1.5))    # passes mid-final-step
    fe.step()                                     # tok2, clock -> 1.0
    fe.step()                                     # starts at 1.0 < 1.5: runs
    assert h.status is Status.DONE and len(h.tokens) == 3
    # sibling one step earlier: deadline passes before the final step starts
    g = fe.submit(_req(1, 4, 3, deadline=clk.t + 0.5))
    fe.step()                                     # tok2, clock passes dl
    fe.step()                                     # expiry check fires first
    assert g.status is Status.EXPIRED and len(g.tokens) == 2
    assert eng.active_count() == 0


def test_deadline_expired_while_queued(lm):
    """Queued expiry never touches the engine: no prefill for the dead
    request, survivors keep their order, slot refilled."""
    eng = _engine(lm, n_slots=1)
    clk = ManualClock()
    fe = ServeFrontend(eng, queue_depth=4, clock=clk)
    a = fe.submit(_req(0, 4, 6))                  # occupies the slot
    b = fe.submit(_req(1, 4, 3, deadline=2.0))    # waits, will die waiting
    c = fe.submit(_req(2, 4, 2))                  # waits behind b
    prefills_before = _prefills(eng)
    clk.advance(5.0)
    fe.step()
    assert b.status is Status.EXPIRED and b.tokens == []
    while not (a.finished and c.finished):
        fe.step()
    assert a.status is Status.DONE and len(a.tokens) == 6
    assert c.status is Status.DONE and len(c.tokens) == 2
    assert _prefills(eng) == prefills_before + 1  # c only, never b


# ---------------------------------------------------------------------------
# backpressure, cancel, streaming
# ---------------------------------------------------------------------------

def test_overload_rejects_with_typed_result(lm):
    eng = _engine(lm, n_slots=1)
    fe = ServeFrontend(eng, queue_depth=1, clock=ManualClock())
    hs = [fe.submit(_req(i, 4, 3)) for i in range(4)]
    rejected = [h for h in hs if h.status is Status.REJECTED]
    assert len(rejected) == 2                     # 1 slot + 1 queue seat
    for h in rejected:
        assert isinstance(h.result, Overloaded)
        assert h.result.queue_depth == 1 and "queue full" in str(h.result)
        assert h.tokens == []
    while fe.step():
        pass
    assert sum(h.status is Status.DONE for h in hs) == 2


def test_cancel_queued_and_running(lm):
    eng = _engine(lm, n_slots=1)
    fe = ServeFrontend(eng, queue_depth=4, clock=ManualClock())
    a = fe.submit(_req(0, 4, 8))
    b = fe.submit(_req(1, 4, 3))
    assert fe.cancel(1) and b.status is Status.CANCELLED and b.tokens == []
    fe.step()
    assert fe.cancel(0) and a.status is Status.CANCELLED
    assert 1 <= len(a.tokens) < 8                 # partials kept
    assert eng.active_count() == 0
    assert not fe.cancel(0)                       # already finished
    assert not fe.cancel(99)                      # unknown rid


def test_gen1_completes_at_admit(lm):
    eng = _engine(lm, n_slots=1)
    fe = ServeFrontend(eng, queue_depth=4, clock=ManualClock())
    h = fe.submit(_req(0, 4, 1))
    assert h.status is Status.DONE and len(h.tokens) == 1
    assert eng.active_count() == 0


def test_stream_yields_before_completion(lm):
    eng = _engine(lm, n_slots=1)
    fe = ServeFrontend(eng, queue_depth=4, clock=ManualClock())
    h = fe.submit(_req(0, 4, 5))
    it = fe.stream(h)
    first = next(it)
    assert not h.finished                         # token before completion
    rest = list(it)
    assert h.status is Status.DONE
    assert [first] + rest == h.tokens and len(h.tokens) == 5


def test_async_streams_interleave(lm):
    eng = _engine(lm, n_slots=2)
    afe = AsyncServeFrontend(ServeFrontend(eng, queue_depth=4))
    order = []

    async def consume(req, tag):
        h = await afe.submit(req)
        toks = []
        async for t in afe.stream(h):
            order.append(tag)
            toks.append(t)
        return toks

    async def main():
        return await asyncio.gather(
            consume(_req(0, 4, 4), "A"), consume(_req(1, 5, 4), "B"))

    ta, tb = asyncio.run(main())
    assert len(ta) == 4 and len(tb) == 4
    # genuinely interleaved: B streams a token before A's stream ends
    last_a = len(order) - 1 - order[::-1].index("A")
    assert order.index("B") < last_a, order


def test_async_driver_task_terminates_when_idle():
    """Regression: the driver task must end (not leak) once every handle
    is terminal and the queue is empty — and restart on a later submit.
    Pure-Python fake engine + injectable clock keep it deterministic."""
    fe = ServeFrontend(FleetFakeEngine(2), queue_depth=4,
                       clock=ManualClock())
    afe = AsyncServeFrontend(fe)

    def req(rid, gen):
        return Request(rid=rid, tokens=np.arange(1, 4, dtype=np.int32),
                       gen=gen)

    async def main():
        h0 = await afe.submit(req(0, 3))
        h1 = await afe.submit(req(1, 2))
        assert len([t async for t in afe.stream(h0)]) == 3
        for _ in range(8):                  # let the driver observe idle
            await asyncio.sleep(0)
        assert h0.finished and h1.finished
        assert afe._task is not None and afe._task.done(), \
            "driver task leaked after all handles terminal + queue empty"
        h2 = await afe.submit(req(2, 2))    # restarts the driver
        assert not afe._task.done()
        assert len([t async for t in afe.stream(h2)]) == 2
        for _ in range(8):
            await afe._asyncio.sleep(0)
        assert afe._task.done()

    asyncio.run(main())


def test_async_driver_terminates_despite_stranded_handle():
    """The exact leak the fix pins: the old exit condition also required
    *every known handle* to be finished, so an unfinished handle stranded
    outside queue/slots kept the driver spinning forever. `not busy`
    alone must end the task."""
    fe = ServeFrontend(FleetFakeEngine(1), queue_depth=4,
                       clock=ManualClock())
    afe = AsyncServeFrontend(fe)

    async def main():
        h = await afe.submit(Request(
            rid=0, tokens=np.arange(1, 4, dtype=np.int32), gen=5))
        # strand it: free the slot behind the front-end's back, so the
        # handle can never reach a terminal state
        slot = next(iter(fe._by_slot))
        fe._by_slot.pop(slot)
        s = fe.engine.slots[slot]
        s.rid, s.req, s.remaining = -1, None, 0
        for _ in range(8):
            await asyncio.sleep(0)
        assert not h.finished
        assert afe._task.done(), "driver spun forever on stranded handle"

    asyncio.run(main())


# ---------------------------------------------------------------------------
# prefix cache
# ---------------------------------------------------------------------------

def test_prefix_cache_exact_and_counted(lm):
    """Requests sharing a 16-token prefix: cached serving produces the
    exact same tokens as cold serving, and the cache counts the hits."""
    model, params = lm
    shared = (np.arange(16) % 5 + 1).astype(np.int32)
    reqs = [Request(rid=i, tokens=np.concatenate(
                [shared, np.full((2,), 10 + i, np.int32)]), gen=4)
            for i in range(4)]

    def serve(prefix_cache):
        eng = ServeEngine(model, params, n_slots=1, max_len=48)
        fe = ServeFrontend(eng, queue_depth=8, prefix_cache=prefix_cache,
                           clock=ManualClock())
        hs = [fe.submit(Request(**vars(r))) for r in reqs]
        while fe.step():
            pass
        return [h.tokens for h in hs], eng

    cache = PrefixCache(cap=4, min_hit=4)
    warm, eng_w = serve(cache)
    cold, _ = serve(None)
    assert warm == cold, "prefix-spliced tokens diverged from cold prefill"
    assert cache.hits == 3 and cache.misses == 1  # first fills, rest hit
    assert cache.reused_tokens == 3 * 16
    assert eng_w.stats["prefix_hits"] == 3


def test_prefix_cache_lru_evicts(lm):
    model, params = lm
    eng = ServeEngine(model, params, n_slots=1, max_len=48)
    cache = PrefixCache(cap=1, min_hit=4)
    fe = ServeFrontend(eng, queue_depth=8, prefix_cache=cache,
                       clock=ManualClock())
    fe.submit(_req(0, 8, 2))
    while fe.step():
        pass
    fe.submit(_req(1, 8, 2))                      # different prompt: evicts
    while fe.step():
        pass
    assert len(cache) == 1 and cache.evictions == 1


def test_prefix_cache_rejected_for_ineligible_stack():
    """swa ring buffers violate the row-locality premise: the front-end
    refuses a prefix cache outright rather than serving wrong tokens."""
    cfg = tiny_cfg("gemma3-1b")
    model = build_model(cfg)
    eng = ServeEngine(model, model.init(jax.random.PRNGKey(0)),
                      n_slots=1, max_len=48)
    assert not eng.prefix_eligible()
    refusal = re.escape(errors.msg("prefix_ineligible", name=cfg.name))
    with pytest.raises(ValueError, match=refusal):
        ServeFrontend(eng, prefix_cache=PrefixCache())
    with pytest.raises(ValueError, match=refusal):
        eng.warmup(prompt_lens=[8], prefix=True)


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------

def test_frontend_table_counts(lm):
    eng = _engine(lm, n_slots=1)
    clk = ManualClock()
    fe = ServeFrontend(eng, queue_depth=1, clock=clk)
    hs = [fe.submit(_req(0, 4, 2)), fe.submit(_req(1, 4, 2)),
          fe.submit(_req(2, 4, 2))]               # third rejected
    while fe.step():
        clk.advance(0.1)
    tab = frontend_table(hs, wall=1.0)
    assert tab["requests"] == 3 and tab["done"] == 2
    assert tab["rejected"] == 1 and tab["expired"] == 0
    assert tab["tokens"] == 4
    assert tab["lat_p50_ms"] >= 0 and tab["ttft_p99_ms"] >= 0


# ---------------------------------------------------------------------------
# engine surfaces the serve suites previously left to the benchmarks
# ---------------------------------------------------------------------------

def test_static_trace_runner_and_percentiles(lm):
    from repro.serve import percentile_table, run_static_trace
    from repro.serve.engine import format_table
    model, params = lm
    trace = synthetic_trace(5, model.cfg.vocab_size, seed=4,
                            prompt_range=(4, 10), gen_range=(2, 5))
    comps = run_static_trace(model, params, trace, n_slots=2, max_len=48)
    assert [c.rid for c in comps] == sorted(r.rid for r in trace)
    tab = percentile_table(comps, max(c.t_done for c in comps))
    assert tab["requests"] == 5
    assert tab["tokens"] == sum(r.gen for r in trace)
    txt = format_table([tab])
    assert txt.startswith("| requests") and "tok_per_s" in txt


def test_warmup_compiles_prefix_path(lm):
    """warmup(prefix=True) pre-compiles the splice path; the first real
    prefix hit then runs without raising and stays token-exact."""
    eng = _engine(lm, n_slots=1)
    eng.warmup(prompt_lens=[8, 10], prefix=True)
    assert eng.active_count() == 0                # reset afterwards
    fe = ServeFrontend(eng, queue_depth=2, prefix_cache=PrefixCache(),
                       clock=ManualClock())
    for i in range(2):
        fe.submit(_req(i, 8, 2))
        while fe.step():
            pass
    assert all(h.status is Status.DONE for h in fe.handles.values())


def test_engine_admit_and_cancel_guards(lm):
    eng = _engine(lm, n_slots=1, max_len=16)
    eng.begin()
    with pytest.raises(ValueError, match=re.escape(
            errors.msg("request_exceeds_max_len", rid=0, prompt=12, gen=8,
                       max_len=16))):
        eng.admit(_req(0, 12, 8), 0)              # 12 + 8 > 16
    with pytest.raises(ValueError, match=re.escape(
            errors.msg("cancel_free_slot", slot=0))):
        eng.cancel(0)                             # nothing running there


# ---------------------------------------------------------------------------
# chunked prefill through the front-end (serve/scheduler.py)
# ---------------------------------------------------------------------------

def test_frontend_chunked_matches_atomic_byte_identical(lm):
    """prefill_chunk must not change a single token: the chunked front-end
    streams byte-identically to the atomic one on the same trace."""
    model, params = lm
    trace = synthetic_trace(n=6, seed=5, rate=50.0, prompt_range=(4, 12),
                            gen_range=(2, 6), vocab=model.cfg.vocab_size)
    base = ServeFrontend(_engine(lm), queue_depth=8).run(trace)
    chunked = ServeFrontend(_engine(lm), queue_depth=8,
                            prefill_chunk=3).run(trace)
    assert all(h.status is Status.DONE for h in chunked)
    for b, c in zip(base, chunked):
        assert b.tokens == c.tokens, f"rid {b.rid}: chunked stream diverged"


def test_deadline_expired_mid_chunked_prefill(lm):
    """Deadline passes between chunks of a cold prefill: the partial
    prefill is discarded outright — ZERO tokens kept (contrast the atomic
    case, which keeps the prefill token), the cancel is counted, and the
    slot is immediately refillable."""
    eng = _engine(lm, n_slots=1)
    clk = ManualClock()
    fe = ServeFrontend(eng, queue_depth=4, clock=clk, prefill_chunk=2)
    h = fe.submit(_req(0, 9, 6, deadline=5.0))    # 9 tokens = 5 chunks
    assert h.status is Status.RUNNING             # PREFILLING: occupied,
    assert h.tokens == []                         # no token yet
    fe.step()                                     # one more chunk
    assert h.status is Status.RUNNING and h.tokens == []
    clk.advance(10.0)                             # deadline passes mid-way
    fe.step()
    assert h.status is Status.EXPIRED
    assert h.tokens == []                         # partial prefill discarded
    assert eng.stats["cancels"] == 1
    assert eng.active_count() == 0                # slot refillable
    g = fe.submit(_req(1, 4, 2))
    while not g.finished:
        fe.step()
    assert g.status is Status.DONE and len(g.tokens) == 2


def test_chunked_prefill_interleaves_with_decode(lm):
    """The tentpole behavior: while a long prompt prefills in chunks, a
    co-resident decoding slot keeps producing a token EVERY step — the
    long admit never freezes it."""
    eng = _engine(lm, n_slots=2)
    clk = ManualClock()
    fe = ServeFrontend(eng, queue_depth=4, clock=clk, prefill_chunk=2)
    short = fe.submit(_req(0, 2, 12))             # one chunk: installs at
    assert short.status is Status.RUNNING         # submit, decodes steadily
    assert len(short.tokens) == 1
    long = fe.submit(_req(1, 10, 2))              # 10 tokens = 4+ chunks
    assert long.status is Status.RUNNING and long.tokens == []
    while long.tokens == [] and not short.finished:
        before = len(short.tokens)
        fe.step()
        assert len(short.tokens) == before + 1, \
            "co-resident decode stalled during chunked prefill"
    assert eng.stats["chunk_steps"] >= 3
    while not (short.finished and long.finished):
        fe.step()
    assert short.status is Status.DONE and long.status is Status.DONE
