"""One benchmark function per paper table/figure.

Each emits ``name,us_per_call,derived`` CSV rows. Scales are CPU-sized but
the protocol matches the paper table it reproduces; EXPERIMENTS.md maps each
one to the paper's claims.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PruneConfig, corp_prune
from repro.models import build_model

from benchmarks.common import (bench_vit_cfg, calib_lm, calib_vit, vit_task_batch,
                               forward_flops, lm_eval_ppl, params_of, row,
                               timeit, trained_lm, trained_vit, vit_eval_acc)


def _prune(model, params, calib, **kw):
    t0 = time.perf_counter()
    out = corp_prune(model, params, calib, PruneConfig(**kw))
    return out, time.perf_counter() - t0


# ---------------------------------------------------------------------------
# Table 2: Top-1 / FLOPs / params at 50% sparsity, MLP / Attn / Both
# ---------------------------------------------------------------------------

def table2_sparsity50():
    cfg, model, params = trained_vit()
    base_acc = vit_eval_acc(model, params)
    b0 = {"images": jax.ShapeDtypeStruct((64, cfg.img_size, cfg.img_size, 3),
                                         jnp.float32)}
    f0 = forward_flops(model, cfg, b0)
    p0 = params_of(params)
    row("table2/base", 0.0,
        f"top1={base_acc:.4f} flops=1.0 params=1.0")
    for tag, (sm, sa) in {"mlp": (0.5, 0.0), "attn": (0.0, 0.5),
                          "both": (0.5, 0.5)}.items():
        calib = calib_vit(cfg)
        (np_, nc, _), dt = _prune(model, params, calib, mlp_sparsity=sm,
                                  attn_sparsity=sa)
        m2 = build_model(nc)
        acc = vit_eval_acc(m2, np_)
        f1 = forward_flops(m2, nc, b0)
        row(f"table2/{tag}", dt * 1e6,
            f"top1={acc:.4f} flops_red={1-f1/f0:.3f} "
            f"param_red={1-params_of(np_)/p0:.3f}")


# ---------------------------------------------------------------------------
# Table 3: calibration-set size
# ---------------------------------------------------------------------------

def table3_calibration():
    cfg, model, params = trained_vit()
    for n in (16, 64, 256):
        calib = calib_vit(cfg, n_samples=n, batch=16)
        (np_, nc, _), dt = _prune(model, params, calib, mlp_sparsity=0.5,
                                  attn_sparsity=0.5)
        acc = vit_eval_acc(build_model(nc), np_)
        row(f"table3/calib_{n}", dt * 1e6, f"top1={acc:.4f}")


# ---------------------------------------------------------------------------
# Table 4 / Fig 3: CORP vs baseline recovery strategies
# ---------------------------------------------------------------------------

def _grail_like(model, params, calib, sparsity):
    """GRAIL-style baseline: prune by magnitude, then post-hoc ridge
    reconstruction of the MODULE OUTPUT on kept hidden activations (refits
    only the second linear; no attention logit compensation)."""
    from repro.core import solve as S
    from repro.core.stats import make_stats_step
    from repro.core.units import discover_units, get_block, set_block
    import copy
    cfg = model.cfg
    units = [u for u in discover_units(cfg) if u.kind == "mlp"]
    step = make_stats_step(model, units, phase=1)
    total = None
    from repro.core.pruner import accumulate, _keep_count, _gather
    total = accumulate(step, params, calib())
    new_params = copy.deepcopy(jax.device_get(params))
    for u in units:
        st = total[u.name]
        block = get_block(new_params, u)
        w2 = jnp.asarray(block["wd"])                 # (R, F, D)
        keep_n = _keep_count(u.d_hidden, sparsity, 1)
        # magnitude ranking (GRAIL's mag variant)
        col = jnp.linalg.norm(w2, axis=-1)
        order = jnp.argsort(-col, axis=-1)
        keep = jnp.sort(order[..., :keep_n], axis=-1)

        def refit(stats_n, s1, s2, keep, w2):
            n = jnp.maximum(stats_n, 1.0)
            mu = s1 / n
            Sig = s2 / n - jnp.outer(mu, mu)
            # module output target: y = h @ W2 ; refit W_S on kept h:
            # W_S* = (Sig_SS + lam)^-1 (Sig_S: @ W2)   [Gram-ridge]
            SS = Sig[jnp.ix_(keep, keep)]
            SA = Sig[keep, :]
            lam = 1e-4 * jnp.mean(jnp.diag(Sig))
            cho = jax.scipy.linalg.cho_factor(
                SS + lam * jnp.eye(keep_n, dtype=Sig.dtype))
            return jax.scipy.linalg.cho_solve(cho, SA @ w2)

        w2_new = jax.vmap(refit)(jnp.asarray(st["n"]) * jnp.ones(w2.shape[0]),
                                 jnp.asarray(st["s1"]),
                                 jnp.asarray(st["s2"]), keep, w2)
        blk = dict(block)
        blk["wd"] = w2_new.astype(w2.dtype)
        for k1 in ("wu", "wg"):
            if k1 in blk:
                blk[k1] = _gather(jnp.asarray(blk[k1]), keep,
                                  axis=blk[k1].ndim - 1)
        for bk in ("bu", "bg"):
            if bk in blk:
                blk[bk] = _gather(jnp.asarray(blk[bk]), keep,
                                  axis=blk[bk].ndim - 1)
        set_block(new_params, u, blk)
    return new_params, cfg.pruned(sparsity, 0.0)


def table4_baselines():
    cfg, model, params = trained_vit()
    calib = calib_vit(cfg)
    s = 0.5
    # CORP
    (p_c, c_c, _), dt = _prune(model, params, calib, mlp_sparsity=s,
                               attn_sparsity=0.0)
    acc_corp = vit_eval_acc(build_model(c_c), p_c)
    # naive (rank-only)
    (p_n, c_n, _), _ = _prune(model, params, calib, mlp_sparsity=s,
                              attn_sparsity=0.0, compensate=False)
    acc_naive = vit_eval_acc(build_model(c_n), p_n)
    # GRAIL-like output reconstruction
    t0 = time.perf_counter()
    p_g, c_g = _grail_like(model, params, calib, s)
    dt_g = time.perf_counter() - t0
    acc_grail = vit_eval_acc(build_model(c_g), p_g)
    row("table4/corp_mlp50", dt * 1e6, f"top1={acc_corp:.4f}")
    row("table4/naive_mlp50", 0.0, f"top1={acc_naive:.4f}")
    row("table4/grail_mlp50", dt_g * 1e6, f"top1={acc_grail:.4f}")
    # attention-only comparison (paper table 4a)
    (p_a, c_a, _), dt = _prune(model, params, calib, mlp_sparsity=0.0,
                               attn_sparsity=s)
    acc_attn = vit_eval_acc(build_model(c_a), p_a)
    (p_an, c_an, _), _ = _prune(model, params, calib, mlp_sparsity=0.0,
                                attn_sparsity=s, compensate=False)
    acc_attn_n = vit_eval_acc(build_model(c_an), p_an)
    row("table4/corp_attn50", dt * 1e6, f"top1={acc_attn:.4f}")
    row("table4/naive_attn50", 0.0, f"top1={acc_attn_n:.4f}")


# ---------------------------------------------------------------------------
# Table 5 / 10: efficiency across sparsity levels
# ---------------------------------------------------------------------------

def table5_efficiency():
    cfg, model, params = trained_vit()
    x1 = jnp.zeros((1, cfg.img_size, cfg.img_size, 3))
    x16 = jnp.zeros((16, cfg.img_size, cfg.img_size, 3))
    b0 = {"images": jax.ShapeDtypeStruct(x16.shape, jnp.float32)}
    f_base = forward_flops(model, cfg, b0)
    p_base = params_of(params)

    fwd = jax.jit(lambda p, x: model.apply(p, {"images": x}))
    lat = timeit(fwd, params, x1)
    tp = 16.0 / timeit(fwd, params, x16)
    acc = vit_eval_acc(model, params)
    row("table5/s0.0", lat * 1e6,
        f"top1={acc:.4f} tput={tp:.0f}ips flops_red=0.000 param_red=0.000")
    for s in (0.3, 0.5, 0.7):
        calib = calib_vit(cfg)
        (np_, nc, _), _ = _prune(model, params, calib, mlp_sparsity=s,
                                 attn_sparsity=s)
        m2 = build_model(nc)
        f2 = jax.jit(lambda p, x: m2.apply(p, {"images": x}))
        lat2 = timeit(f2, np_, x1)
        tp2 = 16.0 / timeit(f2, np_, x16)
        acc2 = vit_eval_acc(m2, np_)
        f1 = forward_flops(m2, nc, b0)
        row(f"table5/s{s}", lat2 * 1e6,
            f"top1={acc2:.4f} tput={tp2:.0f}ips "
            f"flops_red={1-f1/f_base:.3f} "
            f"param_red={1-params_of(np_)/p_base:.3f} "
            f"speedup={tp2/tp:.2f}x")


# ---------------------------------------------------------------------------
# Table 6: runtime breakdown (calibration dominates)
# ---------------------------------------------------------------------------

def table6_runtime():
    cfg, model, params = trained_vit()
    calib = calib_vit(cfg, n_samples=256, batch=16)
    (np_, nc, rep), dt = _prune(model, params, calib, mlp_sparsity=0.5,
                                attn_sparsity=0.5)
    t = rep["timing"]
    total = sum(v for v in t.values())
    row("table6/breakdown", dt * 1e6,
        f"cal={t['pass1']+t.get('pass2',0):.2f}s rank={t['rank']:.3f}s "
        f"comp={t['fold']:.2f}s total={total:.2f}s")


# ---------------------------------------------------------------------------
# Table 7: language model perplexity at 30% sparsity
# ---------------------------------------------------------------------------

def table7_lm():
    cfg, model, params = trained_lm()
    base = lm_eval_ppl(model, params)
    row("table7/base", 0.0, f"ppl={base:.2f}")
    for tag, (sm, sa) in {"mlp": (0.3, 0.0), "attn": (0.0, 0.3),
                          "both": (0.3, 0.3)}.items():
        calib = calib_lm(cfg)
        (np_, nc, _), dt = _prune(model, params, calib, mlp_sparsity=sm,
                                  attn_sparsity=sa)
        ppl = lm_eval_ppl(build_model(nc), np_)
        row(f"table7/{tag}30", dt * 1e6, f"ppl={ppl:.2f}")


# ---------------------------------------------------------------------------
# Table 8: transfer — prune backbone, frozen downstream head
# ---------------------------------------------------------------------------

def table8_transfer():
    """DINOv2 protocol analogue: fit a frozen linear head on dense-backbone
    features, prune ONLY the backbone, re-evaluate the same head."""
    cfg, model, params = trained_vit()
    from repro.data import vit_batch
    from repro.models.vit import apply_vit

    def features(p, c, imgs):
        m = build_model(c)
        # pooled pre-head features: rerun trunk via apply with taps off and
        # grab pooled representation by calling the head-free path
        from repro.models.common import apply_norm
        import repro.models.vit as V
        dt = jnp.dtype(c.dtype)
        x = V.patchify(imgs.astype(dt), c) @ p["patch_w"] \
            + p["patch_b"].astype(dt)
        B, N, D = x.shape
        cls = jnp.broadcast_to(p["cls"], (B, 1, D))
        x = jnp.concatenate([cls, x], 1) + p["pos"][:, :N + 1].astype(dt)
        positions = jnp.broadcast_to(jnp.arange(N + 1, dtype=jnp.int32)[None],
                                     (B, N + 1))
        from repro.models import blocks as blk

        def body(carry, pslice):
            h, _ = blk.apply_block(pslice["p0"], carry, c, "attn", False,
                                   positions=positions, mask_kind="full")
            return h, None
        x, _ = jax.lax.scan(body, x, p["seg0"])
        x = apply_norm(p["final_norm"], x, c)
        return x[:, 0]

    # fit head on a *different* label mapping (transfer task: 5 supercats)
    def task_b(labels):
        return labels % 5
    feats, ys = [], []
    for i in range(8):
        b = vit_task_batch(40_000 + i, 32, cfg.img_size)
        feats.append(np.asarray(features(params, cfg, b["images"])))
        ys.append(task_b(np.asarray(b["labels"])))
    X = np.concatenate(feats)
    Y = np.concatenate(ys)
    # closed-form ridge multiclass head
    Xb = np.concatenate([X, np.ones((len(X), 1))], 1)
    T = np.eye(5)[Y]
    W = np.linalg.solve(Xb.T @ Xb + 1e-2 * np.eye(Xb.shape[1]), Xb.T @ T)

    def head_acc(p, c):
        correct = tot = 0
        for i in range(4):
            b = vit_task_batch(50_000 + i, 32, cfg.img_size)
            f = np.asarray(features(p, c, b["images"]))
            fb = np.concatenate([f, np.ones((len(f), 1))], 1)
            pred = (fb @ W).argmax(-1)
            correct += int((pred == task_b(np.asarray(b["labels"]))).sum())
            tot += 32
        return correct / tot

    acc0 = head_acc(params, cfg)
    calib = calib_vit(cfg)
    (np_, nc, _), dt = _prune(model, params, calib, mlp_sparsity=0.5,
                              attn_sparsity=0.5)
    acc1 = head_acc(np_, nc)
    row("table8/transfer", dt * 1e6,
        f"head_acc {acc0:.4f}->{acc1:.4f} (backbone pruned 50%, head frozen)")


# ---------------------------------------------------------------------------
# Table 9: MLP redundancy analysis (App. A)
# ---------------------------------------------------------------------------

def table9_redundancy():
    cfg, model, params = trained_vit()
    from repro.core.stats import make_stats_step
    from repro.core.units import discover_units
    from repro.core.pruner import accumulate
    units = [u for u in discover_units(cfg) if u.kind == "mlp"]
    stats = accumulate(make_stats_step(model, units, 1), params,
                       calib_vit(cfg, n_samples=256, batch=16)())
    st = stats[units[0].name]
    n = np.maximum(np.asarray(st["n"]), 1)[..., None, None]
    s2 = np.asarray(st["s2"]) / n
    for layer in range(s2.shape[0]):
        ev = np.linalg.eigvalsh(s2[layer])[::-1]
        ev = np.maximum(ev, 0)
        p = ev / ev.sum()
        eff_rank = float(np.exp(-(p * np.log(np.maximum(p, 1e-12))).sum()))
        cum = np.cumsum(ev) / ev.sum()
        k95 = int(np.searchsorted(cum, 0.95) + 1)
        na = np.asarray(st["na"])[layer] / np.asarray(st["n"])[layer] \
            if np.asarray(st["na"]).ndim > 1 else \
            np.asarray(st["na"]) / np.asarray(st["n"])
        sparsity = float((na < 0.05).mean())
        row(f"table9/layer{layer}", 0.0,
            f"dim={s2.shape[-1]} eff_rank={eff_rank:.1f} "
            f"rank_ratio={eff_rank/s2.shape[-1]:.3f} k95={k95} "
            f"act_sparsity={sparsity:.2f}")


# ---------------------------------------------------------------------------
# Fig 2: accuracy vs sparsity, with/without compensation
# ---------------------------------------------------------------------------

def fig2_sparsity_curve():
    cfg, model, params = trained_vit()
    for s in (0.5, 0.7, 0.9):
        calib = calib_vit(cfg)
        (p1, c1, _), dt = _prune(model, params, calib, mlp_sparsity=s,
                                 attn_sparsity=s)
        (p0, c0, _), _ = _prune(model, params, calib, mlp_sparsity=s,
                                attn_sparsity=s, compensate=False)
        a1 = vit_eval_acc(build_model(c1), p1)
        a0 = vit_eval_acc(build_model(c0), p0)
        row(f"fig2/s{s}", dt * 1e6,
            f"top1_comp={a1:.4f} top1_nocomp={a0:.4f} gain={a1-a0:+.4f}")


# ---------------------------------------------------------------------------
# Fig 5: ranking policy ablation
# ---------------------------------------------------------------------------

def fig5_ranking_ablation():
    cfg, model, params = trained_vit()
    from repro.core.ranking import POLICIES
    for policy in POLICIES:
        for comp in (True, False):
            calib = calib_vit(cfg)
            (p1, c1, _), dt = _prune(model, params, calib, mlp_sparsity=0.5,
                                     attn_sparsity=0.5, compensate=comp,
                                     rank_policy=policy)
            a = vit_eval_acc(build_model(c1), p1)
            row(f"fig5/{policy}_{'comp' if comp else 'nocomp'}", dt * 1e6,
                f"top1={a:.4f}")




# ---------------------------------------------------------------------------
# Fig 4: matched-FLOPs comparison — joint MLP+attention vs MLP-only
# ---------------------------------------------------------------------------

def fig4_matched_flops():
    """Paper Fig. 4: distributing sparsity across MLP AND attention beats
    MLP-only pruning at the same FLOPs budget."""
    cfg, model, params = trained_vit()
    b0 = {"images": jax.ShapeDtypeStruct((16, cfg.img_size, cfg.img_size, 3),
                                         jnp.float32)}
    f_base = forward_flops(model, cfg, b0)

    def prune_at(sm, sa):
        (p, c, _), _ = _prune(model, params, calib_vit(cfg), mlp_sparsity=sm,
                              attn_sparsity=sa)
        m2 = build_model(c)
        return vit_eval_acc(m2, p), forward_flops(m2, c, b0) / f_base

    for s_joint in (0.5, 0.7):
        acc_j, fr_j = prune_at(s_joint, s_joint)
        # find the MLP-only sparsity matching the joint FLOPs fraction
        best = None
        for sm in (0.5, 0.6, 0.7, 0.8, 0.9, 0.95):
            acc_m, fr_m = prune_at(sm, 0.0)
            if best is None or abs(fr_m - fr_j) < abs(best[2] - fr_j):
                best = (sm, acc_m, fr_m)
        sm, acc_m, fr_m = best
        row(f"fig4/joint_s{s_joint}", 0.0,
            f"flops={fr_j:.3f} top1_joint={acc_j:.4f} "
            f"top1_mlponly(s={sm})={acc_m:.4f} (flops={fr_m:.3f})")


ALL = [table2_sparsity50, table3_calibration, table4_baselines,
       table5_efficiency, table6_runtime, table7_lm, table8_transfer,
       table9_redundancy, fig2_sparsity_curve, fig4_matched_flops,
       fig5_ranking_ablation]
