"""Shared benchmark fixtures: small trained models (cached across tables).

The paper's experiments need *trained* networks (random weights have nearly
isotropic activations — App. A's redundancy only exists after training), so
each benchmark reuses a DeiT-family ViT and a markov-LM trained for a few
hundred CPU steps and cached under benchmarks/_cache.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, reduced
from repro.data import lm_batch, vit_batch
from repro.launch.train import train
from repro.models import build_model

CACHE = os.path.join(os.path.dirname(__file__), "_cache")

VIT_STEPS = int(os.environ.get("BENCH_VIT_STEPS", "300"))
LM_STEPS = int(os.environ.get("BENCH_LM_STEPS", "300"))


VIT_TASK = {"noise": 2.0, "iid_noise": 0.5, "n_classes": 32}


def bench_vit_cfg():
    return reduced(get_config("deit-base")).replace(
        name="deit-bench", n_layers=4, d_model=96, n_heads=4, n_kv_heads=4,
        d_head=24, d_ff=384, img_size=32, patch=8,
        n_classes=VIT_TASK["n_classes"])


def vit_task_batch(step: int, batch: int, img: int):
    """The benchmark vision task (difficulty tuned so 50-70% naive pruning
    visibly hurts while the dense model sits near ~80%)."""
    return vit_batch(step, batch=batch, img=img,
                     n_classes=VIT_TASK["n_classes"], seed=0,
                     noise=VIT_TASK["noise"], iid_noise=VIT_TASK["iid_noise"])


def bench_lm_cfg():
    return reduced(get_config("qwen2-1.5b")).replace(
        name="lm-bench", n_layers=4, d_model=96, n_heads=4, n_kv_heads=2,
        d_head=24, d_ff=384, vocab_size=256, dtype="float32")


def _cached_train(tag, cfg, steps, batch, seq=48, data_fn=None):
    ckpt_dir = os.path.join(CACHE, tag)
    model = build_model(cfg)
    last = latest_step(ckpt_dir)
    if last is not None and last >= steps:
        params = model.init(jax.random.PRNGKey(0))
        (params, _), _ = restore_checkpoint(ckpt_dir, last, (params, None))
        return model, params
    if data_fn is None:
        params, _opt, _losses = train(cfg, steps=steps, batch=batch, seq=seq,
                                      ckpt_dir=None, peak_lr=2e-3,
                                      log=lambda *a: None)
    else:
        from repro.optim import AdamWConfig, adamw_init, adamw_update
        ocfg = AdamWConfig()
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params, ocfg)

        @jax.jit
        def step_fn(p, o, b):
            loss, g = jax.value_and_grad(lambda pp: model.loss(pp, b))(p)
            return (*adamw_update(p, g, o, 2e-3, ocfg)[:2], loss)

        for s in range(steps):
            params, opt, _ = step_fn(params, opt, data_fn(s, batch))
    save_checkpoint(ckpt_dir, steps, (params, None))
    return model, params


def trained_vit():
    cfg = bench_vit_cfg()
    return cfg, *_cached_train(
        "vit", cfg, VIT_STEPS, batch=64,
        data_fn=lambda s, b: vit_task_batch(s, b, cfg.img_size))


def trained_lm():
    cfg = bench_lm_cfg()
    return cfg, *_cached_train("lm", cfg, LM_STEPS, batch=16, seq=48)


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def vit_eval_acc(model, params, *, n=512, seed=9_000):
    cfg = model.cfg
    correct = total = 0
    f = jax.jit(lambda p, x: model.apply(p, {"images": x}))
    for i in range(n // 64):
        b = vit_task_batch(seed + i, 64, cfg.img_size)
        logits = f(params, b["images"])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == b["labels"]))
        total += 64
    return correct / total


def lm_eval_ppl(model, params, *, n=8, seed=9_500):
    cfg = model.cfg
    tot, cnt = 0.0, 0

    @jax.jit
    def nll(p, b):
        return model.loss(p, b, train=False)
    for i in range(n):
        b = lm_batch(seed + i, batch=8, seq=48, vocab=cfg.vocab_size, seed=0)
        tot += float(nll(params, b)) * 8 * 48
        cnt += 8 * 48
    return float(np.exp(tot / cnt))


def calib_vit(cfg, n_samples=128, batch=16):
    steps = max(1, n_samples // batch)

    def make():
        for i in range(steps):
            b = vit_task_batch(20_000 + i, batch, cfg.img_size)
            yield {"images": b["images"]}
    return make


def calib_lm(cfg, n_samples=64, batch=8, seq=48):
    steps = max(1, n_samples // batch)

    def make():
        for i in range(steps):
            b = lm_batch(30_000 + i, batch=batch, seq=seq,
                         vocab=cfg.vocab_size, seed=0)
            yield {"tokens": b["tokens"]}
    return make


# ---------------------------------------------------------------------------
# flops / timing
# ---------------------------------------------------------------------------

def forward_flops(model, cfg, batch):
    from repro.roofline.analysis import jaxpr_matmul_flops
    return jaxpr_matmul_flops(lambda p, b: model.apply(p, b),
                              jax.eval_shape(lambda: model.init(
                                  jax.random.PRNGKey(0))), batch)


def params_of(params) -> int:
    return int(sum(x.size for x in jax.tree.leaves(params)))


def timeit(fn, *args, warmup=2, iters=10):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
