"""Sharded CalibrationEngine: per-device Sigma footprint + parity gate.

The sharded engine exists for one number: the largest statistic any single
device must hold. Unsharded, every dense unit's second moment is a full
(F, F) fp32 Sigma per device (1.3 GB at d_ff=18432); column-sharded over an
m-way model axis it is (F, F/m). This benchmark builds a forced 4-device
host mesh (2 data x 2 model), runs both engines on the same stream, and

  * asserts fp32 statistic parity (the sharded engine must be a pure
    re-layout of the single-device sums);
  * asserts no accumulator leaf of a dense unit is replicated — the
    addressable shard's trailing dim is F/m, checked from the live arrays;
  * reports per-device resident statistic bytes for both layouts and the
    wall-clock of each pass (host-simulated sharding adds interconnect-free
    collective overhead, so tokens/sec here is NOT the TPU story — the
    footprint column is the point).

Run:  PYTHONPATH=src python benchmarks/bench_calib_sharded.py
(sets the forced device count itself; do not preset JAX_PLATFORMS/XLA_FLAGS)
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.launch.mesh import force_host_devices  # noqa: E402

force_host_devices(4)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.core import CalibrationEngine, discover_units  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402


def _batches(cfg, n, B, seed=0):
    k = jax.random.PRNGKey(seed)
    return [{"images": jax.random.normal(
        jax.random.fold_in(k, i), (B, cfg.img_size, cfg.img_size, 3))}
        for i in range(n)]


def _device_bytes(acc, sharded: bool) -> int:
    """Largest per-device resident statistic footprint."""
    total = 0
    for leaf in jax.tree.leaves(acc):
        if sharded:
            total += max(s.data.nbytes for s in leaf.addressable_shards)
        else:
            total += np.asarray(leaf).nbytes
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-base")
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=8)
    args = ap.parse_args()

    assert len(jax.devices()) >= 4, jax.devices()
    cfg = reduced(get_config(args.arch)).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    units = discover_units(cfg)
    batches = _batches(cfg, args.batches, args.batch_size)
    mesh = make_mesh((2, 2))

    single = CalibrationEngine(model, units, phase=1)
    sharded = CalibrationEngine(model, units, phase=1, mesh=mesh)

    def timed(engine):
        t0 = time.perf_counter()
        out = engine.run(params, batches)
        jax.block_until_ready(jax.tree.leaves(out))
        return out, time.perf_counter() - t0

    s_single, t_single = timed(single)
    s_sharded, t_sharded = timed(sharded)

    # parity: the sharded engine is a re-layout, not a re-derivation
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4),
        s_sharded, s_single)

    # bf16 streaming composes with sharding: per-shard gram_cross ingests
    # bf16 local tiles, accumulators stay fp32 — must hold the same Sigma
    # tolerance as the unsharded bf16 gate (one shared metric + tolerance)
    from benchmarks.bench_calibration import BF16_SIGMA_TOL, sigma_relerr
    sharded_bf16 = CalibrationEngine(model, units, phase=1, mesh=mesh,
                                     stats_dtype="bfloat16")
    s_bf16, t_bf16 = timed(sharded_bf16)
    err = sigma_relerr(s_sharded, s_bf16)
    assert err <= BF16_SIGMA_TOL, (
        f"sharded bf16 stream Sigma relerr {err:.2e} > {BF16_SIGMA_TOL:.0e}")

    # footprint, measured on live accumulators
    acc1 = single.init_stats(params, batches[0])
    acc2 = sharded.init_stats(params, batches[0])
    b_single = _device_bytes(acc1, sharded=False)
    b_sharded = _device_bytes(acc2, sharded=True)
    m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    for u in units:
        if u.kind in ("mlp", "rwkv_mlp", "mamba"):
            s2 = acc2[u.name]["s2"]
            local = s2.addressable_shards[0].data.shape
            assert local[-1] == s2.shape[-1] // m, (u.name, local, s2.shape)

    print("name,us_per_call,derived")
    print(f"calib_single_device,{t_single*1e6:.0f},"
          f"{b_single} B/device stats")
    print(f"calib_sharded_2x2,{t_sharded*1e6:.0f},"
          f"{b_sharded} B/device stats "
          f"({b_single/b_sharded:.2f}x smaller, parity OK)")
    print(f"calib_sharded_bf16_stream,{t_bf16*1e6:.0f},"
          f"sigma_relerr={err:.2e} vs fp32-stream sharded (tol 1e-2)")
    assert b_sharded < b_single, (b_sharded, b_single)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
