"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table5,table7]

Prints ``name,us_per_call,derived`` CSV. EXPERIMENTS.md maps every row to
the paper table it reproduces and the claim it validates.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated prefixes, e.g. table5,fig2")
    args = ap.parse_args()
    from benchmarks import tables
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failures = 0
    for fn in tables.ALL:
        if only and not any(fn.__name__.startswith(p) for p in only):
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:        # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{fn.__name__},0.0,ERROR", flush=True)
        print(f"# {fn.__name__} took {time.perf_counter()-t0:.1f}s",
              file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
