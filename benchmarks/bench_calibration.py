"""Calibration throughput: fused CalibrationEngine vs per-unit loop.

CORP's entire cost is the calibration pass, so this is the number behind the
paper's "under 20 minutes on a single GPU" claim. Two ways to gather the
same pass-1 statistics:

  legacy  — one jitted statistics step PER UNIT, each re-running the full
            model forward for its taps (what a naive per-unit implementation
            does; identical to corp_prune_streamed with unit_group_size=1),
            with host-side tree-adds between batches;
  fused   — repro.core.calibrate.CalibrationEngine: ONE jitted step per
            batch reduces every unit's statistics from a single forward,
            accumulating into a donated on-device pytree.

Both produce identical statistics (linearity); the fused engine does ~1/U
of the forward work for U units plus zero host round-trips, so its
tokens/sec must come out >= the loop — asserted at the end so regressions
fail loudly in CI.

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python benchmarks/bench_calibration.py
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.core import CalibrationEngine, discover_units  # noqa: E402
from repro.core import stats as stats_mod  # noqa: E402
from repro.models import build_model  # noqa: E402


def _batches(cfg, n, B, seed=0):
    k = jax.random.PRNGKey(seed)
    return [{"images": jax.random.normal(
        jax.random.fold_in(k, i), (B, cfg.img_size, cfg.img_size, 3))}
        for i in range(n)]


def _tokens(cfg, batches):
    n_tok = (cfg.img_size // cfg.patch) ** 2 + 1      # patches + cls
    return sum(b["images"].shape[0] for b in batches) * n_tok


def build_legacy_steps(model, units):
    """One separately-jitted stats step per unit, built once so repeats
    measure execution (forwards + host tree-adds), not retracing."""
    return [jax.jit(stats_mod.make_stats_step(model, [u], phase=1))
            for u in units]


def run_legacy(steps, params, batches):
    """Per-unit loop: each unit's step re-runs the model forward for its
    taps, with a host-side tree-add between batches."""
    merged = {}
    for step in steps:
        total = None
        for batch in batches:
            total = stats_mod.tree_add(total, step(params, batch))
        merged.update(jax.device_get(total))
    return merged


def run_fused(engine, params, batches):
    return engine.run(params, batches)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-base")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    units = discover_units(cfg)
    batches = _batches(cfg, args.batches, args.batch_size)
    n_tok = _tokens(cfg, batches)
    engine = CalibrationEngine(model, units, phase=1)
    legacy_steps = build_legacy_steps(model, units)

    # warmup both paths (compile), check parity while we are at it
    fused0 = run_fused(engine, params, batches[:1])
    legacy0 = run_legacy(legacy_steps, params, batches[:1])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4), fused0, legacy0)

    def timeit(fn):
        best = float("inf")
        for _ in range(args.repeats):
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(jax.tree.leaves(out))
            best = min(best, time.perf_counter() - t0)
        return best

    t_legacy = timeit(lambda: run_legacy(legacy_steps, params, batches))
    t_fused = timeit(lambda: run_fused(engine, params, batches))
    tps_legacy = n_tok / t_legacy
    tps_fused = n_tok / t_fused

    print("name,us_per_call,derived")
    print(f"calib_legacy_per_unit_loop,{t_legacy*1e6:.0f},"
          f"{tps_legacy:.0f} tok/s ({len(units)} units)")
    print(f"calib_fused_engine,{t_fused*1e6:.0f},"
          f"{tps_fused:.0f} tok/s (speedup {t_legacy/t_fused:.2f}x)")

    assert tps_fused >= tps_legacy, (
        f"fused engine slower than per-unit loop: "
        f"{tps_fused:.0f} < {tps_legacy:.0f} tok/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
