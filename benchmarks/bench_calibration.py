"""Calibration throughput: fused CalibrationEngine vs per-unit loop, plus
the bf16-streaming and gram-autotune gates.

CORP's entire cost is the calibration pass, so this is the number behind the
paper's "under 20 minutes on a single GPU" claim. Three gates:

  fused >= legacy — one jitted statistics step PER UNIT, each re-running
            the full model forward for its taps, with host-side tree-adds
            (the naive per-unit implementation) vs ONE jitted step per
            batch reducing every unit's statistics from a single forward
            into a donated on-device pytree. Identical statistics
            (linearity); the fused engine must not be slower.

  bf16 streaming — `stats_dtype="bfloat16"` must (a) halve the activation
            bytes the calibration pass streams (measured on the real tap
            tape; ~2x, the moe mask stays fp32) and (b) stay within the
            documented Sigma tolerance of the fp32 stream
            (max|Δs2| / max|s2| <= 1e-2, see docs/kernels.md — accumulators
            are fp32 either way, only per-tap rounding differs).

  autotune — the roofline-autotuned (bf, bn) must never be predicted
            slower than the fixed legacy 128/512 tiles on the benchmark
            shapes (the candidate set contains 128/512, so a regression
            here means the cost model inverted; see
            repro.kernels.gram.autotune).

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python benchmarks/bench_calibration.py
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.core import CalibrationEngine, discover_units  # noqa: E402
from repro.core import stats as stats_mod  # noqa: E402
from repro.kernels.gram import autotune  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models import common as model_common  # noqa: E402

#: documented bf16-stream Sigma tolerance (docs/kernels.md): max abs
#: second-moment deviation relative to the largest fp32 entry.
BF16_SIGMA_TOL = 1e-2


def _batches(cfg, n, B, seed=0):
    k = jax.random.PRNGKey(seed)
    return [{"images": jax.random.normal(
        jax.random.fold_in(k, i), (B, cfg.img_size, cfg.img_size, 3))}
        for i in range(n)]


def _tokens(cfg, batches):
    n_tok = (cfg.img_size // cfg.patch) ** 2 + 1      # patches + cls
    return sum(b["images"].shape[0] for b in batches) * n_tok


def build_legacy_steps(model, units):
    """One separately-jitted stats step per unit, built once so repeats
    measure execution (forwards + host tree-adds), not retracing."""
    return [jax.jit(stats_mod.make_stats_step(model, [u], phase=1))
            for u in units]


def run_legacy(steps, params, batches):
    """Per-unit loop: each unit's step re-runs the model forward for its
    taps, with a host-side tree-add between batches."""
    merged = {}
    for step in steps:
        total = None
        for batch in batches:
            total = stats_mod.tree_add(total, step(params, batch))
        merged.update(jax.device_get(total))
    return merged


def run_fused(engine, params, batches):
    return engine.run(params, batches)


def tap_bytes(model, params, batch, stats_dtype) -> int:
    """Bytes the calibration pass streams per batch in activation taps —
    the HBM traffic the stats_dtype knob is meant to halve."""
    taps = {}
    with model_common.tap_dtype(stats_dtype):
        model.apply(params, batch, taps=taps)
    return sum(np.asarray(t).size * np.asarray(t).dtype.itemsize
               for t in jax.tree.leaves(taps))


def sigma_relerr(fp32_stats, bf16_stats) -> float:
    """max over dense units of max|s2_bf16 - s2_fp32| / max|s2_fp32|."""
    worst = 0.0
    for name, st in fp32_stats.items():
        if "s2" not in st:
            continue
        a = np.asarray(st["s2"], np.float64)
        b = np.asarray(bf16_stats[name]["s2"], np.float64)
        worst = max(worst, float(np.max(np.abs(a - b)) /
                                 max(np.max(np.abs(a)), 1e-30)))
    return worst


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-base")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    units = discover_units(cfg)
    batches = _batches(cfg, args.batches, args.batch_size)
    n_tok = _tokens(cfg, batches)
    engine = CalibrationEngine(model, units, phase=1)
    legacy_steps = build_legacy_steps(model, units)

    # warmup both paths (compile), check parity while we are at it
    fused0 = run_fused(engine, params, batches[:1])
    legacy0 = run_legacy(legacy_steps, params, batches[:1])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4), fused0, legacy0)

    def timeit(fn):
        """-> (best seconds, last output) — callers reuse the output so
        gates never re-run a pass they already timed."""
        best = float("inf")
        out = None
        for _ in range(args.repeats):
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(jax.tree.leaves(out))
            best = min(best, time.perf_counter() - t0)
        return best, out

    t_legacy, _ = timeit(lambda: run_legacy(legacy_steps, params, batches))
    t_fused, stats_fp32 = timeit(lambda: run_fused(engine, params, batches))
    tps_legacy = n_tok / t_legacy
    tps_fused = n_tok / t_fused

    print("name,us_per_call,derived")
    print(f"calib_legacy_per_unit_loop,{t_legacy*1e6:.0f},"
          f"{tps_legacy:.0f} tok/s ({len(units)} units)")
    print(f"calib_fused_engine,{t_fused*1e6:.0f},"
          f"{tps_fused:.0f} tok/s (speedup {t_legacy/t_fused:.2f}x)")

    assert tps_fused >= tps_legacy, (
        f"fused engine slower than per-unit loop: "
        f"{tps_fused:.0f} < {tps_legacy:.0f} tok/s")

    # --- gate 2: bf16 streaming — ~2x less activation traffic at parity --
    eng_bf16 = CalibrationEngine(model, units, phase=1,
                                 stats_dtype="bfloat16")
    t_bf16, stats_bf16 = timeit(lambda: run_fused(eng_bf16, params, batches))
    b_fp32 = tap_bytes(model, params, batches[0], jnp.float32)
    b_bf16 = tap_bytes(model, params, batches[0], jnp.bfloat16)
    err = sigma_relerr(stats_fp32, stats_bf16)
    print(f"calib_bf16_stream,{t_bf16*1e6:.0f},"
          f"{b_fp32/b_bf16:.2f}x fewer activation bytes "
          f"({b_fp32} -> {b_bf16} B/batch), sigma_relerr={err:.2e}")
    assert b_fp32 / b_bf16 >= 1.9, (
        f"bf16 streaming saved only {b_fp32/b_bf16:.2f}x activation bytes")
    assert err <= BF16_SIGMA_TOL, (
        f"bf16-stream Sigma deviates {err:.2e} > {BF16_SIGMA_TOL:.0e} "
        f"from the fp32 stream")

    # --- gate 3: autotuned tiles never predicted slower than 128/512 -----
    shapes = sorted({(args.batch_size * ((cfg.img_size // cfg.patch) ** 2
                                         + 1), cfg.d_ff)}
                    | set(autotune.DEFAULT_SHAPES))
    worst = 1e9
    for (n, f) in shapes:
        for dt in ("float32", "bfloat16"):
            bf, bn = autotune.choose_tiles(n, f, dt)
            t_auto = autotune.predicted_time(n, f, dt, bf, bn)
            t_fixed = autotune.predicted_time(n, f, dt, 128, 512)
            assert t_auto <= t_fixed, (
                f"autotuned ({bf},{bn}) predicted slower than fixed "
                f"(128,512) on N={n} F={f} {dt}: {t_auto} > {t_fixed}")
            worst = min(worst, t_fixed / t_auto)
    print(f"calib_gram_autotune,0,predicted >= fixed 128/512 on "
          f"{len(shapes)}x2 shapes (min speedup {worst:.2f}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
