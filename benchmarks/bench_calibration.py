"""Calibration throughput: fused CalibrationEngine vs per-unit loop, plus
the bf16-streaming and gram-autotune gates.

CORP's entire cost is the calibration pass, so this is the number behind the
paper's "under 20 minutes on a single GPU" claim. Three gates:

  fused >= legacy — one jitted statistics step PER UNIT, each re-running
            the full model forward for its taps, with host-side tree-adds
            (the naive per-unit implementation) vs ONE jitted step per
            batch reducing every unit's statistics from a single forward
            into a donated on-device pytree. Identical statistics
            (linearity); the fused engine must not be slower.

  bf16 streaming — `stats_dtype="bfloat16"` must (a) halve the activation
            bytes the calibration pass streams (measured on the real tap
            tape; ~2x, the moe mask stays fp32) and (b) stay within the
            documented Sigma tolerance of the fp32 stream
            (max|Δs2| / max|s2| <= 1e-2, see docs/kernels.md — accumulators
            are fp32 either way, only per-tap rounding differs).

  autotune — the roofline-autotuned (bf, bn) must never be predicted
            slower than the fixed legacy 128/512 tiles on the benchmark
            shapes (the candidate set contains 128/512, so a regression
            here means the cost model inverted; see
            repro.kernels.gram.autotune).

With ``--one-traversal`` the script instead runs the speculative-fusion
gates (docs/pipeline.md):

  hit-rate  — margin sweep on the DeiT (class-1) and granite (rope) reduced
            configs: candidates from the first batch's running scores vs
            final keep-sets from the full stream, plus the measured
            speculative-accumulator memory overhead per margin. Emitted as
            the markdown table docs/pipeline.md cites (``--table-out``
            writes it to a file; the CI job uploads it so the doc's
            numbers can be audited against a fresh run).

  traversals == 1 — corp_prune(one_traversal=True) at the smallest
            all-hit margin from the sweep must consume the calibration
            stream exactly once (zero misses) and produce a pruned model
            functionally identical to the two-pass baseline.

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python benchmarks/bench_calibration.py
      JAX_PLATFORMS=cpu PYTHONPATH=src python benchmarks/bench_calibration.py \\
          --one-traversal --table-out /tmp/hit_rate.md
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.core import CalibrationEngine, discover_units  # noqa: E402
from repro.core import stats as stats_mod  # noqa: E402
from repro.kernels.gram import autotune  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models import common as model_common  # noqa: E402

#: documented bf16-stream Sigma tolerance (docs/kernels.md): max abs
#: second-moment deviation relative to the largest fp32 entry.
BF16_SIGMA_TOL = 1e-2


def _batches(cfg, n, B, seed=0):
    k = jax.random.PRNGKey(seed)
    return [{"images": jax.random.normal(
        jax.random.fold_in(k, i), (B, cfg.img_size, cfg.img_size, 3))}
        for i in range(n)]


def _tokens(cfg, batches):
    n_tok = (cfg.img_size // cfg.patch) ** 2 + 1      # patches + cls
    return sum(b["images"].shape[0] for b in batches) * n_tok


def build_legacy_steps(model, units):
    """One separately-jitted stats step per unit, built once so repeats
    measure execution (forwards + host tree-adds), not retracing."""
    return [jax.jit(stats_mod.make_stats_step(model, [u], phase=1))
            for u in units]


def run_legacy(steps, params, batches):
    """Per-unit loop: each unit's step re-runs the model forward for its
    taps, with a host-side tree-add between batches."""
    merged = {}
    for step in steps:
        total = None
        for batch in batches:
            total = stats_mod.tree_add(total, step(params, batch))
        merged.update(jax.device_get(total))
    return merged


def run_fused(engine, params, batches):
    return engine.run(params, batches)


def tap_bytes(model, params, batch, stats_dtype) -> int:
    """Bytes the calibration pass streams per batch in activation taps —
    the HBM traffic the stats_dtype knob is meant to halve."""
    taps = {}
    with model_common.tap_dtype(stats_dtype):
        model.apply(params, batch, taps=taps)
    return sum(np.asarray(t).size * np.asarray(t).dtype.itemsize
               for t in jax.tree.leaves(taps))


def sigma_relerr(fp32_stats, bf16_stats) -> float:
    """max over dense units of max|s2_bf16 - s2_fp32| / max|s2_fp32|."""
    worst = 0.0
    for name, st in fp32_stats.items():
        if "s2" not in st:
            continue
        a = np.asarray(st["s2"], np.float64)
        b = np.asarray(bf16_stats[name]["s2"], np.float64)
        worst = max(worst, float(np.max(np.abs(a - b)) /
                                 max(np.max(np.abs(a)), 1e-30)))
    return worst


# ---------------------------------------------------------------------------
# one-traversal speculative gates (--one-traversal)
# ---------------------------------------------------------------------------

SPEC_MARGINS = (0.0, 0.125, 0.25, 0.5, 1.0)
SPEC_ARCHS = ("deit-base", "granite-8b")


def _tree_bytes(shapes) -> int:
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in jax.tree.leaves(shapes))


def _spec_sweep(arch: str, n_batches: int, batch_size: int):
    """Hit-rate + memory-overhead rows for one arch across SPEC_MARGINS.

    Candidates come from the FIRST batch's ranking scores (exactly what
    ``corp_prune(one_traversal=True)`` uses), final keep-sets from the full
    stream; hit-rate counts covered (unit, layer, group) rows. Memory is
    ``jax.eval_shape`` of the speculative accumulators vs the dedicated
    pass-2 accumulators for the same plan.
    """
    from repro.core import ranking as rank_mod
    from repro.core.pruner import _keep_count
    from repro.data import calib_stream

    cfg = reduced(get_config(arch)).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    units = discover_units(cfg)
    attn_units = [u for u in units if u.kind in ("attn", "mla", "cross")]
    stream = calib_stream(cfg, n_samples=n_batches * batch_size,
                          batch=batch_size)
    batches = list(stream())
    eng1 = CalibrationEngine(model, units, phase=1)
    s0 = eng1.run(params, batches[:1])          # running scores, batch 0
    s_all = eng1.run(params, batches)           # final scores, full stream

    plan, keep_ns = {}, {}
    for u in attn_units:
        full = s_all[u.name]["rank"].shape[-1]
        # the 50% protocol via the SAME rounding the gate's corp_prune
        # uses, so the sweep's hit margins transfer to the gate exactly
        keep_ns[u.name] = _keep_count(full, 0.5, 1)
        plan[u.name] = rank_mod.rank_attn(s_all[u.name], keep_ns[u.name])
    e2 = CalibrationEngine(model, units, phase=2, plan=plan)
    p2_bytes = _tree_bytes(jax.eval_shape(e2._reduce, params, batches[0]))

    rows = []
    for margin in SPEC_MARGINS:
        spec_plan = {u.name: rank_mod.candidate_attn(
            s0[u.name], keep_ns[u.name], margin) for u in attn_units}
        total = hit = 0
        for u in attn_units:
            cand = spec_plan[u.name]
            keep = np.asarray(plan[u.name][0])
            c2 = cand.reshape(-1, cand.shape[-1])
            k2 = keep.reshape(-1, keep.shape[-1])
            for cr, kr in zip(c2, k2):
                total += 1
                hit += bool(np.isin(kr, cr).all())
        es = CalibrationEngine(model, units, phase="1+2",
                               spec_plan=spec_plan)
        spec_bytes = _tree_bytes(jax.eval_shape(
            es._reduce, params, batches[0])["p2spec"])
        rows.append({"arch": arch, "margin": margin,
                     "cand": int(next(iter(spec_plan.values())).shape[-1]),
                     "keep": keep_ns[next(iter(keep_ns))],
                     "hit_rate": hit / max(total, 1),
                     "mem_ratio": spec_bytes / max(p2_bytes, 1)})
    return rows


def one_traversal_gates(args) -> int:
    """--one-traversal mode: hit-rate table + the traversal-count gate."""
    from repro.core import PruneConfig, corp_prune
    from repro.data import calib_stream

    rows = []
    for arch in SPEC_ARCHS:
        rows += _spec_sweep(arch, args.batches, args.batch_size)

    lines = ["| arch | margin | candidates/keep | hit-rate | spec mem / "
             "pass-2 mem |",
             "|---|---|---|---|---|"]
    for r in rows:
        lines.append(f"| {r['arch']} | {r['margin']:.3f} | "
                     f"{r['cand']}/{r['keep']} | {r['hit_rate']:.2f} | "
                     f"{r['mem_ratio']:.2f}x |")
    table = "\n".join(lines)
    print(table)
    if args.table_out:
        with open(args.table_out, "w") as f:
            f.write("# One-traversal speculative calibration: margin vs "
                    "hit-rate vs memory\n\n"
                    "Generated by `benchmarks/bench_calibration.py "
                    "--one-traversal` (consumed by docs/pipeline.md).\n\n"
                    + table + "\n")
        print(f"# wrote {args.table_out}")

    # gate: at the smallest all-hit margin, corp_prune must traverse ONCE
    # and match the two-pass baseline functionally
    print("name,us_per_call,derived")
    for arch in SPEC_ARCHS:
        margins = [r["margin"] for r in rows
                   if r["arch"] == arch and r["hit_rate"] >= 1.0]
        assert margins, f"{arch}: no margin reaches hit-rate 1.0 " \
                        f"(sweep {SPEC_MARGINS})"
        margin = min(margins)
        cfg = reduced(get_config(arch)).replace(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        stream = calib_stream(cfg, n_samples=args.batches * args.batch_size,
                              batch=args.batch_size)
        pc = PruneConfig(0.5, 0.5)
        t0 = time.perf_counter()
        p_two, c_two, r_two = corp_prune(model, params, stream, pc)
        t_two = time.perf_counter() - t0
        t0 = time.perf_counter()
        p_one, c_one, r_one = corp_prune(model, params, stream, pc,
                                         one_traversal=True,
                                         spec_margin=margin)
        t_one = time.perf_counter() - t0
        assert r_one["traversals"] == 1, (
            f"{arch}: one-traversal hit path consumed "
            f"{r_one['traversals']} traversals (misses: "
            f"{r_one['speculative']['misses']})")
        assert not r_one["speculative"]["misses"], r_one["speculative"]
        assert c_two == c_one
        # functional parity: the class-1 SVD fold is gauge-unique only up
        # to paired singular-vector signs, so compare pruned-model outputs
        m2 = build_model(c_two)
        batch = next(iter(stream()))
        y_two = m2.apply(p_two, batch)
        y_one = m2.apply(p_one, batch)
        y_two = y_two[0] if isinstance(y_two, tuple) else y_two
        y_one = y_one[0] if isinstance(y_one, tuple) else y_one
        np.testing.assert_allclose(np.asarray(y_two, np.float32),
                                   np.asarray(y_one, np.float32),
                                   rtol=1e-4, atol=1e-5)
        print(f"calib_one_traversal_{arch},{t_one*1e6:.0f},"
              f"margin={margin} traversals {r_two['traversals']}->1, "
              f"two-pass {t_two:.2f}s vs {t_one:.2f}s")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-base")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--one-traversal", action="store_true",
                    help="run the speculative one-traversal gates instead "
                         "of the throughput/bf16/autotune gates: margin vs "
                         "hit-rate table + traversals==1 on the hit path")
    ap.add_argument("--table-out", default=None,
                    help="with --one-traversal: also write the hit-rate "
                         "markdown table to this path (uploaded by CI, "
                         "cited by docs/pipeline.md)")
    args = ap.parse_args()
    if args.one_traversal:
        return one_traversal_gates(args)

    cfg = reduced(get_config(args.arch)).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    units = discover_units(cfg)
    batches = _batches(cfg, args.batches, args.batch_size)
    n_tok = _tokens(cfg, batches)
    engine = CalibrationEngine(model, units, phase=1)
    legacy_steps = build_legacy_steps(model, units)

    # warmup both paths (compile), check parity while we are at it
    fused0 = run_fused(engine, params, batches[:1])
    legacy0 = run_legacy(legacy_steps, params, batches[:1])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4), fused0, legacy0)

    def timeit(fn):
        """-> (best seconds, last output) — callers reuse the output so
        gates never re-run a pass they already timed."""
        best = float("inf")
        out = None
        for _ in range(args.repeats):
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(jax.tree.leaves(out))
            best = min(best, time.perf_counter() - t0)
        return best, out

    t_legacy, _ = timeit(lambda: run_legacy(legacy_steps, params, batches))
    t_fused, stats_fp32 = timeit(lambda: run_fused(engine, params, batches))
    tps_legacy = n_tok / t_legacy
    tps_fused = n_tok / t_fused

    print("name,us_per_call,derived")
    print(f"calib_legacy_per_unit_loop,{t_legacy*1e6:.0f},"
          f"{tps_legacy:.0f} tok/s ({len(units)} units)")
    print(f"calib_fused_engine,{t_fused*1e6:.0f},"
          f"{tps_fused:.0f} tok/s (speedup {t_legacy/t_fused:.2f}x)")

    assert tps_fused >= tps_legacy, (
        f"fused engine slower than per-unit loop: "
        f"{tps_fused:.0f} < {tps_legacy:.0f} tok/s")

    # --- gate 2: bf16 streaming — ~2x less activation traffic at parity --
    eng_bf16 = CalibrationEngine(model, units, phase=1,
                                 stats_dtype="bfloat16")
    t_bf16, stats_bf16 = timeit(lambda: run_fused(eng_bf16, params, batches))
    b_fp32 = tap_bytes(model, params, batches[0], jnp.float32)
    b_bf16 = tap_bytes(model, params, batches[0], jnp.bfloat16)
    err = sigma_relerr(stats_fp32, stats_bf16)
    print(f"calib_bf16_stream,{t_bf16*1e6:.0f},"
          f"{b_fp32/b_bf16:.2f}x fewer activation bytes "
          f"({b_fp32} -> {b_bf16} B/batch), sigma_relerr={err:.2e}")
    assert b_fp32 / b_bf16 >= 1.9, (
        f"bf16 streaming saved only {b_fp32/b_bf16:.2f}x activation bytes")
    assert err <= BF16_SIGMA_TOL, (
        f"bf16-stream Sigma deviates {err:.2e} > {BF16_SIGMA_TOL:.0e} "
        f"from the fp32 stream")

    # --- gate 3: autotuned tiles never predicted slower than 128/512 -----
    shapes = sorted({(args.batch_size * ((cfg.img_size // cfg.patch) ** 2
                                         + 1), cfg.d_ff)}
                    | set(autotune.DEFAULT_SHAPES))
    worst = 1e9
    for (n, f) in shapes:
        for dt in ("float32", "bfloat16"):
            bf, bn = autotune.choose_tiles(n, f, dt)
            t_auto = autotune.predicted_time(n, f, dt, bf, bn)
            t_fixed = autotune.predicted_time(n, f, dt, 128, 512)
            assert t_auto <= t_fixed, (
                f"autotuned ({bf},{bn}) predicted slower than fixed "
                f"(128,512) on N={n} F={f} {dt}: {t_auto} > {t_fixed}")
            worst = min(worst, t_fixed / t_auto)
    print(f"calib_gram_autotune,0,predicted >= fixed 128/512 on "
          f"{len(shapes)}x2 shapes (min speedup {worst:.2f}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
