"""Mesh-sharded ServeEngine: cross-device parity + per-device footprint.

The live-mesh mirror of ``bench_serve.py``'s analytic 671B gate: a forced
4-device host mesh (2 data x 2 model), one engine per contract family
(kv = deepseek-7b, recurrent = rwkv6-3b, MoE/MLA = deepseek-v3-671b, all
reduced), and three gates:

  * token parity — the sharded engine must stream token-identical to the
    single-device engine on the same ragged trace (slots refill
    mid-flight, so the sharded scatter-admit and shard-local resets are
    both on the hook);
  * measured == analytic footprint — every engine's live per-device
    slot-cache bytes (max addressable shard per leaf) must EXACTLY equal
    ``device_bytes_estimate`` of its specs, and sit at ~1/(data*model)
    of the unsharded cache (replicated ``pos`` bookkeeping is the only
    slack);
  * pruned < dense per device — 50% CORP pruning must shrink the kv
    config's per-device cache strictly below the dense sharded one
    (``eff_qk`` composes with the 1/N model split).

The tok/s column is reported, not gated: host-simulated sharding pays
interconnect-free collective overhead, so decode speed here is NOT the
TPU story — the parity and footprint columns are the point (same stance
as benchmarks/bench_calib_sharded.py).

Run:  PYTHONPATH=src python benchmarks/bench_serve_sharded.py \
          --table-out sharded_serve.md
(sets the forced device count itself; do not preset JAX_PLATFORMS/XLA_FLAGS)
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.launch.mesh import force_host_devices  # noqa: E402

force_host_devices(4)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.bench_serve import _zoo_cfg  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve import (ServeEngine, ServeSharding,  # noqa: E402
                         device_bytes_estimate, synthetic_trace)
from repro.serve.engine import format_table  # noqa: E402

SLOTS = 2
MAX_LEN = 48
ARCHS = ("deepseek-7b", "rwkv6-3b", "deepseek-v3-671b")


def _timed_run(eng, trace):
    eng.warmup(prompt_lens=[len(r.tokens) for r in trace])
    t0 = time.perf_counter()
    comps = eng.run(trace)
    return comps, time.perf_counter() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--table-out", default=None,
                    help="write the footprint + scaling markdown table "
                         "here (CI uploads it as an artifact)")
    args = ap.parse_args()

    assert len(jax.devices()) >= 4, jax.devices()
    mesh = make_mesh((2, 2))
    sharding = ServeSharding(mesh)
    n_dev = sharding.data_size * sharding.model_size

    rows = []
    for arch in ARCHS:
        cfg = _zoo_cfg(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(11)
        trace = synthetic_trace(6, cfg.vocab_size, seed=int(rng.randint(99)),
                                prompt_range=(4, 12), gen_range=(2, 8))
        single = ServeEngine(model, params, n_slots=SLOTS, max_len=MAX_LEN)
        shard = ServeEngine(model, params, n_slots=SLOTS, max_len=MAX_LEN,
                            sharding=sharding)
        comps_1, wall_1 = _timed_run(single, trace)
        comps_s, wall_s = _timed_run(shard, trace)

        # gate: token-identical streams (mid-flight retire/refill included:
        # 6 requests through 2 slots)
        for a, b in zip(comps_1, comps_s):
            assert list(a.tokens) == list(b.tokens), (
                f"{arch}: sharded stream diverged on rid {a.rid}")
        assert single.stats["refills"] > 0, "trace never refilled a slot"

        # gate: live per-device bytes == analytic estimate of the specs
        est = device_bytes_estimate(shard.slotcache._template,
                                    shard.slotcache.specs, sharding.sizes)
        assert shard.device_cache_bytes == est, (
            f"{arch}: measured per-device bytes {shard.device_cache_bytes}"
            f" != analytic {est}")
        split = single.cache_bytes / shard.device_cache_bytes
        assert split >= 0.9 * n_dev, (
            f"{arch}: per-device cache only {split:.2f}x smaller on a "
            f"{n_dev}-device mesh")

        total = sum(len(c.tokens) for c in comps_s)
        rows.append({"arch": cfg.name, "contract": shard.contract,
                     "cache_kb": single.cache_bytes / 1e3,
                     "per_device_kb": shard.device_cache_bytes / 1e3,
                     "split": split,
                     "tok_per_s_single": total / max(wall_1, 1e-9),
                     "tok_per_s_sharded": total / max(wall_s, 1e-9)})
        print(f"[bench_serve_sharded] GATE parity {arch}: "
              f"{len(comps_s)} streams token-identical, per-device "
              f"{shard.device_cache_bytes / 1e3:.1f} kB = analytic, "
              f"{split:.2f}x split")

    # gate: CORP pruning shrinks the per-device cache strictly further
    cfg = _zoo_cfg("deepseek-7b")
    pcfg = cfg.pruned(0.5, 0.5)
    dense = ServeEngine(build_model(cfg),
                        build_model(cfg).init(jax.random.PRNGKey(0)),
                        n_slots=SLOTS, max_len=MAX_LEN, sharding=sharding)
    pruned = ServeEngine(build_model(pcfg),
                         build_model(pcfg).init(jax.random.PRNGKey(0)),
                         n_slots=SLOTS, max_len=MAX_LEN, sharding=sharding)
    assert pruned.device_cache_bytes < dense.device_cache_bytes, (
        f"pruned per-device cache not smaller: "
        f"{pruned.device_cache_bytes} >= {dense.device_cache_bytes}")
    rows.append({"arch": f"{pcfg.name}", "contract": pruned.contract,
                 "cache_kb": pruned.cache_bytes / 1e3,
                 "per_device_kb": pruned.device_cache_bytes / 1e3,
                 "split": dense.cache_bytes / pruned.device_cache_bytes,
                 "tok_per_s_single": float("nan"),
                 "tok_per_s_sharded": float("nan")})
    print(f"[bench_serve_sharded] GATE pruned < dense per device: "
          f"{pruned.device_cache_bytes / 1e3:.1f} < "
          f"{dense.device_cache_bytes / 1e3:.1f} kB "
          f"(eff_qk {cfg.eff_qk} -> {pcfg.eff_qk} on top of the "
          f"1/{sharding.model_size} model split)")

    table = format_table(rows)
    print(table)
    if args.table_out:
        with open(args.table_out, "w") as f:
            f.write("# Mesh-sharded serving (2 data x 2 model forced host "
                    "mesh)\n\nPer-device slot-cache footprint and decode "
                    "scaling; tok/s is host-simulated (collective overhead "
                    "without an interconnect) — the footprint and parity "
                    "columns are the gated story.\n\n" + table + "\n")
        print(f"[bench_serve_sharded] table -> {args.table_out}")
    print("[bench_serve_sharded] all gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
