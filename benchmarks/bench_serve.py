"""Serving benchmark: continuous batching vs static fixed batches, and the
dense-vs-pruned serving table (the paper's Table-5 efficiency protocol on
the serve path — docs/serving.md).

Gates:

  continuous >= static — on a ragged arrival trace (mixed prompt/gen
            lengths) the slot-refilling engine must reach at least the
            throughput of the fixed-batch baseline, which pads every batch
            to its longest prompt and decodes until its longest generation
            finishes. Both are compile-warmed; the win is the removed
            batch barrier, not compile time.

  token parity — continuous and static serving of the same trace must
            produce identical greedy streams (slot refills cannot
            contaminate neighbours).

  pruned cache < dense — a 50% CORP-pruned model's preallocated slot cache
            must be smaller than the dense one (qk dims shrink the K rows),
            with the dense/pruned serving table printed for the docs.

Front-end gates (ISSUE 6):

  frontend == engine — the async front-end's token streams must be
            byte-identical to ``ServeEngine.run`` on the same trace
            (no deadlines, no prefix cache).

  overload rejects, never deadlocks — a burst of 3x capacity must shed
            exactly the overflow with typed rejections, serve the rest to
            completion, and keep p99 ttft bounded by the run's wall time.

  prefix hit < cold prefill — admitting a prompt whose 96-token prefix is
            cached must beat a cold full prefill on median ttft (printed
            as the prefix-hit vs cold table).

Fleet gates (ISSUE 7):

  routed 4x >= 3x single — a 4-replica ReplicaRouter over fixed-cost
            fake engines (each decode step sleeps a known wall time) must
            reach at least 3x one replica's throughput on the same ragged
            trace: the router steps replicas concurrently, so N decode
            steps cost ~one step of wall time. FakeEngine-backed so the
            gate is meaningful on CPU CI.

  routed == engine — greedy streams served through a 2-replica fleet of
            real engines must be token-identical to a single engine
            serving the same trace (routing cannot change the math).

  drain — draining a replica mid-trace completes its in-flight requests,
            admits nothing new to it afterwards, and keeps p99 latency
            bounded by the run's wall time.

Config-zoo gates (ISSUE 8):

  recurrent slot bytes constant — a pure-recurrent stack (rwkv6) must
            hold per-slot state bytes EXACTLY constant as max_len grows
            4x, while the pure-KV reference grows near-linearly — the
            serving win of the recurrent slot-cache contract
            (docs/serving.md "Slot-cache contracts").

  expert-pruned serving — a 50%-expert CORP-pruned MoE must serve
            through the engine token-identical to its own full greedy
            forward at the smaller expert count, with the compensated
            fold inside parity tolerance of naive expert dropping.

Scheduler gates (ISSUE 10):

  chunked interference — a 64-token prompt arriving (x3) while two slots
            decode steadily must not freeze them: with chunked prefill
            the co-resident decode-gap p99 must be STRICTLY below the
            unchunked engine's, with identical token streams. Fixed-cost
            fake engine (1 ms/decode step, 1 ms/prompt token), so the
            gate measures the scheduler's interleaving, not device speed.

  chunked == atomic — greedy streams with ``prefill_chunk`` set must be
            byte-identical to the atomic engine's across the kv,
            recurrent and MoE slot-cache contracts (real engines).

  enc-dec mixed load — an encoder-burst + steady-decode trace served
            through the chunked scheduler gets its own p50/p99 row,
            byte-identical to the atomic engine; printed and written to
            scheduler_trace.md together with the interference table.

Sharded gate (ISSUE 9):

  671B-class footprint — the FULL jamba-1.5-large-398b / deepseek-v3-671b
            slot caches on a (data=2, model=8) mesh must be ~1/16 per
            device (analytic: dict-mesh ``slot_specs`` over eval_shape
            templates, no devices needed), with 50% CORP pruning
            shrinking the hybrid's per-device cache strictly further.
            The live-mesh mirror (token parity, measured shards, tok/s
            scaling table) is benchmarks/bench_serve_sharded.py.

Run:  JAX_PLATFORMS=cpu PYTHONPATH=src python benchmarks/bench_serve.py
      (--table-out routed_trace.md writes the routed-trace p50/p99 table;
       --sched-table-out scheduler_trace.md writes the chunked-prefill
       interference + mixed-load tables)
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

from benchmarks.common import calib_lm, params_of, trained_lm  # noqa: E402
from repro.core import PruneConfig, corp_prune  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve import (PrefixCache, ReplicaRouter,  # noqa: E402
                         ServeEngine, ServeFrontend, Status,
                         frontend_table, percentile_table,
                         run_static_trace, synthetic_trace)
from repro.serve.engine import Request, format_table  # noqa: E402
from repro.serve.testing import FleetFakeEngine  # noqa: E402

SLOTS = 4
MAX_LEN = 128
TRACE = dict(prompt_range=(8, 48), gen_range=(4, 48), seed=0)


def serve_continuous(model, params, trace):
    eng = ServeEngine(model, params, n_slots=SLOTS, max_len=MAX_LEN)
    eng.warmup(prompt_lens=[len(r.tokens) for r in trace])
    t0 = time.perf_counter()
    comps = eng.run(trace)
    wall = time.perf_counter() - t0
    return comps, percentile_table(comps, wall), eng


def serve_static(model, params, trace):
    # run_static_trace compile-warms its own buckets outside its timed
    # region, so wall time comes from the completions' own clock
    comps = run_static_trace(model, params, trace, n_slots=SLOTS,
                             max_len=MAX_LEN)
    wall = max(c.t_done for c in comps)
    return comps, percentile_table(comps, wall)


def gate_frontend_parity(model, params, trace, comps_engine):
    """Front-end streams must be byte-identical to the engine's runner."""
    import numpy as np
    eng = ServeEngine(model, params, n_slots=SLOTS, max_len=MAX_LEN)
    eng.warmup(prompt_lens=[len(r.tokens) for r in trace])
    handles = ServeFrontend(eng, queue_depth=len(trace)).run(trace)
    by_rid = {c.rid: c for c in comps_engine}
    for h in handles:
        assert h.status is Status.DONE, f"rid {h.rid} ended {h.status}"
        assert h.tokens == list(np.asarray(by_rid[h.rid].tokens)), (
            f"front-end stream diverged from engine on rid {h.rid}")
    print("[bench_serve] GATE frontend == engine: "
          f"{len(handles)} token streams byte-identical")


def gate_overload(model, params, vocab):
    """3x-capacity burst: shed the overflow, finish the rest, stay live."""
    depth = SLOTS
    n = 3 * (SLOTS + depth)
    trace = synthetic_trace(n, vocab, seed=2, prompt_range=(8, 24),
                            gen_range=(4, 16))       # all arrive at t=0
    eng = ServeEngine(model, params, n_slots=SLOTS, max_len=MAX_LEN)
    eng.warmup(prompt_lens=[len(r.tokens) for r in trace])
    fe = ServeFrontend(eng, queue_depth=depth)
    t0 = time.perf_counter()
    handles = fe.run(trace)
    wall = time.perf_counter() - t0
    tab = frontend_table(handles, wall)
    print(format_table([tab], ["requests", "done", "rejected", "tokens",
                               "ttft_p50_ms", "ttft_p99_ms"]))
    assert tab["rejected"] == n - SLOTS - depth, (
        f"expected {n - SLOTS - depth} rejections, got {tab['rejected']}")
    assert tab["done"] == SLOTS + depth
    assert tab["ttft_p99_ms"] <= wall * 1e3, "ttft unbounded under overload"
    print(f"[bench_serve] GATE overload: {tab['rejected']}/{n} shed, "
          f"{tab['done']} served, ttft p99 {tab['ttft_p99_ms']:.1f} ms "
          f"<= wall {wall * 1e3:.1f} ms")


def gate_prefix_ttft(model, params):
    """Median prefix-hit admit must beat a cold full prefill."""
    import numpy as np
    eng = ServeEngine(model, params, n_slots=1, max_len=MAX_LEN)
    eng.warmup(prompt_lens=[97], prefix=True)
    eng.begin()
    shared = (np.arange(96) % 7 + 1).astype(np.int32)
    pc = PrefixCache(cap=4, min_hit=8)

    def admit_ms(rid, cache):
        toks = np.concatenate([shared, np.full((1,), 20 + rid, np.int32)])
        t0 = time.perf_counter()
        eng.admit(Request(rid=rid, tokens=toks, gen=2), 0,
                  prefix_cache=cache)
        dt = (time.perf_counter() - t0) * 1e3
        eng.retire(0)
        return dt

    cold = [admit_ms(i, None) for i in range(8)]
    admit_ms(100, pc)                                # prime the cache
    warm = [admit_ms(200 + i, pc) for i in range(8)]
    cold_med, warm_med = float(np.median(cold)), float(np.median(warm))
    print(format_table([
        {"admit": "cold prefill", "ttft_p50_ms": cold_med},
        {"admit": "prefix hit", "ttft_p50_ms": warm_med}]))
    assert warm_med < cold_med, (
        f"prefix hit not faster: {warm_med:.2f} vs {cold_med:.2f} ms")
    assert eng.stats["prefix_hits"] == 8
    print(f"[bench_serve] GATE prefix hit < cold prefill: "
          f"{warm_med:.2f} < {cold_med:.2f} ms "
          f"({pc.stats()['reused_tokens']} tokens reused)")


def _fake_fleet_run(n_replicas, trace, *, slots, step_time):
    """Serve ``trace`` through ``n_replicas`` fixed-cost fake engines
    behind the router (1 replica = bare engine) and return the
    percentile table."""
    engines = [FleetFakeEngine(slots, step_time=step_time)
               for _ in range(n_replicas)]
    eng = engines[0] if n_replicas == 1 else ReplicaRouter(engines)
    fe = ServeFrontend(eng, queue_depth=len(trace))
    t0 = time.perf_counter()
    handles = fe.run(trace)
    wall = time.perf_counter() - t0
    assert all(h.status is Status.DONE for h in handles)
    return frontend_table(handles, wall)


def gate_fleet_throughput(table_out=None):
    """Routed N=4 fleet must reach >= 3x a single replica's throughput
    on the same ragged trace (fixed-cost fake decode steps, so the gate
    measures router concurrency, not device speed)."""
    # step_time dominates per-step python/thread-dispatch overhead, so
    # the ratio reflects concurrent replica stepping, not interpreter cost
    slots, step_time = 4, 8e-3
    trace = synthetic_trace(64, 256, seed=3, prompt_range=(4, 12),
                            gen_range=(16, 48))       # all arrive at t=0
    single = _fake_fleet_run(1, trace, slots=slots, step_time=step_time)
    fleet = _fake_fleet_run(4, trace, slots=slots, step_time=step_time)
    single["mode"], fleet["mode"] = "single", "routed-x4"
    keys = ["mode", "requests", "tokens", "tok_per_s", "lat_p50_ms",
            "lat_p99_ms", "ttft_p50_ms", "ttft_p99_ms"]
    table = format_table([single, fleet], keys)
    print(table)
    if table_out:
        with open(table_out, "w") as f:
            f.write("# Routed-trace latency (4-replica fleet vs single "
                    "replica, fixed-cost fake engines)\n\n" + table + "\n")
        print(f"[bench_serve] routed-trace table -> {table_out}")
    ratio = fleet["tok_per_s"] / single["tok_per_s"]
    assert ratio >= 3.0, (
        f"routed x4 fleet below 3x single-replica throughput: "
        f"{fleet['tok_per_s']:.0f} vs {single['tok_per_s']:.0f} tok/s "
        f"({ratio:.2f}x)")
    print(f"[bench_serve] GATE routed 4x >= 3x single: "
          f"{fleet['tok_per_s']:.0f} >= 3x {single['tok_per_s']:.0f} "
          f"tok/s ({ratio:.2f}x)")


def gate_fleet_parity(model, params, trace, comps_engine):
    """Streams through a 2-replica fleet of real engines must be
    token-identical to one engine serving the same trace."""
    import numpy as np
    engines = []
    for _ in range(2):
        e = ServeEngine(model, params, n_slots=SLOTS, max_len=MAX_LEN)
        e.warmup(prompt_lens=[len(r.tokens) for r in trace])
        engines.append(e)
    router = ReplicaRouter(engines)
    handles = ServeFrontend(router, queue_depth=len(trace)).run(trace)
    by_rid = {c.rid: c for c in comps_engine}
    for h in handles:
        assert h.status is Status.DONE, f"rid {h.rid} ended {h.status}"
        assert h.tokens == list(np.asarray(by_rid[h.rid].tokens)), (
            f"routed stream diverged from single engine on rid {h.rid}")
    spread = [e.stats["admits"] for e in engines]
    assert all(s > 0 for s in spread), f"one-sided routing: {spread}"
    print(f"[bench_serve] GATE routed == engine: {len(handles)} streams "
          f"token-identical across a 2-replica fleet (admits {spread})")


def gate_drain():
    """Drain completes in-flight, admits nothing new to the drained
    replica, and keeps p99 latency bounded by the run's wall time."""
    trace = synthetic_trace(16, 256, seed=4, prompt_range=(4, 8),
                            gen_range=(8, 16))
    engines = [FleetFakeEngine(2, step_time=1e-3) for _ in range(2)]
    router = ReplicaRouter(engines)
    fe = ServeFrontend(router, queue_depth=len(trace))
    handles = [fe.submit(r) for r in trace]
    t0 = time.perf_counter()
    fe.step()                                # first slots bound + stepped
    router.drain(0)
    admits0 = engines[0].stats["admits"]
    while not all(h.finished for h in handles):
        fe.step()
    wall = time.perf_counter() - t0
    assert all(h.status is Status.DONE for h in handles)
    assert engines[0].stats["admits"] == admits0, (
        f"admissions to a draining replica: {engines[0].stats['admits']} "
        f"> {admits0}")
    assert router.drained(0), "drained replica still reports in-flight"
    tab = frontend_table(handles, wall)
    assert tab["lat_p99_ms"] <= wall * 1e3, "p99 unbounded under drain"
    print(f"[bench_serve] GATE drain: {tab['done']} served, "
          f"{admits0} admits frozen on replica 0, drained(0)=True, "
          f"p99 {tab['lat_p99_ms']:.1f} <= wall {wall * 1e3:.1f} ms")


def _zoo_cfg(arch):
    """Reduced float32 config for the zoo gates (capacity bumped on MoE so
    routing never drops tokens and greedy parity is exact)."""
    import dataclasses
    from repro.configs import get_config, reduced
    cfg = reduced(get_config(arch)).replace(dtype="float32")
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


def _chain_ok(model, params, req, out_tokens):
    """One-full-forward greedy self-consistency (tests/helpers.py)."""
    import jax.numpy as jnp
    import numpy as np
    P = len(req.tokens)
    seq = np.concatenate([np.asarray(req.tokens, np.int32),
                          np.asarray(out_tokens[:-1], np.int32)])
    logits = model.apply(params, {"tokens": jnp.asarray(seq)[None]})[0]
    pred = np.asarray(jnp.argmax(logits[0, :, : model.cfg.vocab_size], -1))
    return list(pred[P - 1: P - 1 + len(out_tokens)]) == \
        [int(t) for t in out_tokens]


def gate_recurrent_state_bytes():
    """Pure-recurrent per-slot state bytes must be EXACTLY constant in
    max_len (64 -> 256) while the pure-KV reference grows; the recurrent
    engine must actually serve at that budget."""
    built = {}
    rows = []
    for arch in ("rwkv6-3b", "qwen2-1.5b"):
        cfg = _zoo_cfg(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        small = ServeEngine(model, params, n_slots=2, max_len=64)
        large = ServeEngine(model, params, n_slots=2, max_len=256)
        built[arch] = (small, large, model, params, cfg)
        rows.append({"arch": cfg.name, "contract": small.contract,
                     "slot_kb_len64": small.slotcache.slot_bytes / 1e3,
                     "slot_kb_len256": large.slotcache.slot_bytes / 1e3,
                     "growth": large.slotcache.slot_bytes
                     / small.slotcache.slot_bytes})
    print(format_table(rows))
    rec_s, rec_l, model, params, cfg = built["rwkv6-3b"]
    kv_s, kv_l = built["qwen2-1.5b"][:2]
    assert rec_s.contract == "recurrent" and kv_s.contract == "kv"
    assert rec_l.slotcache.slot_bytes == rec_s.slotcache.slot_bytes, (
        f"recurrent slot bytes grew with max_len: "
        f"{rec_s.slotcache.slot_bytes} -> {rec_l.slotcache.slot_bytes}")
    kv_growth = kv_l.slotcache.slot_bytes / kv_s.slotcache.slot_bytes
    assert kv_growth > 1.5, f"KV reference did not grow ({kv_growth:.2f}x)"
    trace = synthetic_trace(4, cfg.vocab_size, seed=6,
                            prompt_range=(4, 10), gen_range=(2, 6))
    comps = rec_s.run(trace)
    for r, c in zip(trace, comps):
        assert len(c.tokens) == r.gen
        assert _chain_ok(model, params, r, c.tokens), r.rid
    print(f"[bench_serve] GATE recurrent slot bytes constant: "
          f"{rec_s.slotcache.slot_bytes / 1e3:.1f} kB at max_len 64 AND "
          f"256 (KV reference grows {kv_growth:.2f}x); "
          f"{len(comps)} recurrent streams match the full forward")


def gate_expert_pruned_serving():
    """50%-expert CORP prune: compensated fold within parity tolerance of
    naive dropping, and the pruned MoE serves through the engine
    token-identical to its own full greedy forward."""
    import jax.numpy as jnp
    import numpy as np
    from repro.data import lm_batch
    cfg = _zoo_cfg("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calib_lm(cfg, n_samples=32, batch=4, seq=24)
    batch = {"tokens": lm_batch(41_000, batch=4, seq=24,
                                vocab=cfg.vocab_size, seed=0)["tokens"]}
    y0 = model.apply(params, batch)[0]

    errs, kept = {}, {}
    for comp in (True, False):
        new_p, new_c, _ = corp_prune(
            model, params, calib,
            PruneConfig(0.0, 0.0, expert_sparsity=0.5, compensate=comp))
        pm = build_model(new_c)
        y1 = pm.apply(new_p, batch)[0]
        errs[comp] = float(jnp.mean(jnp.square(
            (y1 - y0).astype(jnp.float32))))
        kept[comp] = (new_c.eff_num_experts, new_p, new_c, pm)
    n_kept = kept[True][0]
    assert n_kept < cfg.moe.num_experts
    print(format_table([
        {"model": "dense", "experts": cfg.moe.num_experts, "mse": 0.0},
        {"model": "experts dropped", "experts": n_kept,
         "mse": errs[False]},
        {"model": "experts folded", "experts": n_kept, "mse": errs[True]},
    ]))
    assert errs[True] <= errs[False] * 1.25, (
        f"expert compensation outside parity tolerance: {errs}")

    _, new_p, new_c, pm = kept[True]
    rng = np.random.RandomState(8)
    reqs = [Request(rid=i, tokens=rng.randint(
        0, cfg.vocab_size, size=p).astype(np.int32), gen=g)
        for i, (p, g) in enumerate([(5, 3), (9, 4), (4, 2), (7, 3)])]
    comps = ServeEngine(pm, new_p, n_slots=2, max_len=24).run(reqs)
    for r, c in zip(reqs, comps):
        assert len(c.tokens) == r.gen
        assert _chain_ok(pm, new_p, r, c.tokens), r.rid
    print(f"[bench_serve] GATE expert-pruned serving: "
          f"{cfg.moe.num_experts} -> {n_kept} experts, fold mse "
          f"{errs[True]:.4f} <= 1.25x naive {errs[False]:.4f}; "
          f"{len(comps)} pruned streams match the full forward")


class _CostedFakeEngine(FleetFakeEngine):
    """FleetFakeEngine whose admits cost ``tok_time`` wall seconds per
    prompt token consumed (atomic admits pay the whole prompt in one call,
    chunked admits pay per chunk), so the decode gap a co-resident stream
    sees IS the scheduler's interleaving policy, not device speed."""

    def __init__(self, n_slots, *, step_time=0.0, tok_time=0.0):
        super().__init__(n_slots, step_time=step_time)
        self.tok_time = tok_time

    def continue_admit(self, slot, budget=None):
        s = self.slots[slot]
        if s.pending is not None and self.tok_time:
            take = s.pending if budget is None \
                else min(max(1, int(budget)), s.pending)
            time.sleep(take * self.tok_time)       # releases the GIL
        return super().continue_admit(slot, budget)


def _interference_run(chunk, *, arrivals=3, plen=64, tok_time=1e-3):
    """Two steady decoders + ``arrivals`` sequential max-length prompts
    through one fixed-cost engine; returns (per-iteration decode gaps,
    long-prompt token streams)."""
    import numpy as np
    eng = _CostedFakeEngine(3, step_time=1e-3, tok_time=tok_time)
    fe = ServeFrontend(eng, queue_depth=8, prefill_chunk=chunk)
    steadies = [fe.submit(Request(rid=i, tokens=np.arange(2, dtype=np.int32),
                                  gen=10_000)) for i in range(2)]
    for _ in range(2):
        fe.step()                                  # both steadies decoding
    gaps, longs = [], []
    while len(longs) < arrivals or not all(h.finished for h in longs):
        # the gap window spans submit + step: atomic admits prefill inside
        # submit (free slot), chunked admits prefill inside step — the
        # co-resident stream stalls for the duration either way
        t0 = time.perf_counter()
        if len(longs) < arrivals and (not longs or longs[-1].finished):
            longs.append(fe.submit(Request(
                rid=100 + len(longs),
                tokens=np.zeros(plen, np.int32), gen=4)))
        fe.step()
        gaps.append(time.perf_counter() - t0)
        assert len(gaps) < 500, "interference scenario did not converge"
    for h in steadies:
        fe.cancel(h.rid)
    assert all(h.status is Status.DONE and len(h.tokens) == 4
               for h in longs)
    return gaps, [h.tokens for h in longs]


def gate_chunked_interference(chunk=8):
    """Chunked prefill must strictly beat the atomic engine on co-resident
    decode-gap p99 when max-length prompts arrive mid-decode, with the
    long prompts' token streams unchanged. Returns the markdown table."""
    import numpy as np
    rows, streams, p99 = [], {}, {}
    for label, c in (("unchunked", None), (f"chunked-{chunk}", chunk)):
        gaps, toks = _interference_run(c)
        streams[label], p99[label] = toks, float(np.percentile(gaps, 99))
        rows.append({"mode": label, "iters": len(gaps),
                     "gap_p50_ms": float(np.percentile(gaps, 50)) * 1e3,
                     "gap_p99_ms": p99[label] * 1e3})
    table = format_table(rows)
    print(table)
    a, b = streams["unchunked"], streams[f"chunked-{chunk}"]
    assert a == b, "chunking changed the long prompts' token streams"
    assert p99[f"chunked-{chunk}"] < p99["unchunked"], (
        f"chunked decode-gap p99 not strictly better: "
        f"{p99[f'chunked-{chunk}'] * 1e3:.1f} vs "
        f"{p99['unchunked'] * 1e3:.1f} ms")
    print(f"[bench_serve] GATE chunked interference: decode-gap p99 "
          f"{p99[f'chunked-{chunk}'] * 1e3:.1f} < "
          f"{p99['unchunked'] * 1e3:.1f} ms (x3 64-token arrivals, "
          f"streams identical)")
    return table


def gate_chunked_identity(model, params, trace, comps_engine):
    """Chunked greedy streams must be byte-identical to the atomic
    engine's across the kv, recurrent and MoE slot-cache contracts."""
    import numpy as np
    checks = [("kv(trained)", model, params, trace, MAX_LEN, 7,
               comps_engine)]
    for arch, chunk in (("rwkv6-3b", 3), ("qwen3-moe-235b-a22b", 3)):
        cfg = _zoo_cfg(arch)
        m = build_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        tr = synthetic_trace(4, cfg.vocab_size, seed=9,
                             prompt_range=(4, 12), gen_range=(2, 6))
        checks.append((cfg.name, m, p, tr, 32, chunk, None))
    for name, m, p, tr, max_len, chunk, ref in checks:
        if ref is None:
            ref = ServeEngine(m, p, n_slots=2, max_len=max_len).run(tr)
        comps = ServeEngine(m, p, n_slots=2, max_len=max_len).run(
            tr, prefill_chunk=chunk)
        by_rid = {c.rid: c for c in ref}
        for c in comps:
            assert list(np.asarray(c.tokens)) == \
                list(np.asarray(by_rid[c.rid].tokens)), (
                    f"{name}: chunked stream diverged on rid {c.rid}")
        print(f"[bench_serve] GATE chunked == atomic [{name}]: "
              f"{len(comps)} streams byte-identical at chunk {chunk}")


def mixedload_encdec_row(chunk=4):
    """Enc-dec mixed load through the chunked scheduler: an encoder burst
    (frames + long prompts, short gens) lands on top of steady decoders
    (short prompts, long gens). Returns the p50/p99 markdown row; streams
    must match the atomic engine's."""
    import numpy as np
    cfg = _zoo_cfg("seamless-m4t-large-v2")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mem = 10
    rng = np.random.RandomState(11)

    def req(rid, p, g):
        return Request(rid=rid, tokens=rng.randint(
            0, cfg.vocab_size, size=p).astype(np.int32), gen=g,
            frames=rng.randn(mem, cfg.d_model).astype(np.float32))

    steady = [req(i, 3, 10) for i in range(2)]            # decode-bound
    burst = [req(10 + i, 12, 2) for i in range(4)]        # encoder-bound
    trace = steady + burst
    eng = ServeEngine(model, params, n_slots=2, max_len=32, mem_len=mem)
    eng.run([req(90, 12, 2), req(91, 3, 3)],
            prefill_chunk=chunk)                          # compile-warm
    ref = {c.rid: c for c in
           ServeEngine(model, params, n_slots=2, max_len=32,
                       mem_len=mem).run(trace)}
    t0 = time.perf_counter()
    handles = ServeFrontend(eng, queue_depth=len(trace),
                            prefill_chunk=chunk).run(trace)
    wall = time.perf_counter() - t0
    for h in handles:
        assert h.status is Status.DONE, f"rid {h.rid} ended {h.status}"
        assert h.tokens == list(np.asarray(ref[h.rid].tokens)), (
            f"enc-dec chunked stream diverged on rid {h.rid}")
    tab = frontend_table(handles, wall)
    tab["mode"] = f"encdec-mixed (chunk {chunk})"
    table = format_table([tab], ["mode", "requests", "done", "tokens",
                                 "lat_p50_ms", "lat_p99_ms",
                                 "ttft_p50_ms", "ttft_p99_ms"])
    print(table)
    print(f"[bench_serve] GATE enc-dec mixed load: {len(handles)} chunked "
          f"streams byte-identical to the atomic engine")
    return table


def gate_sharded_footprint():
    """Mesh-sharded serving at 671B scale, analytically (ISSUE 9): the
    per-device slot-cache bytes of the FULL ``jamba-1.5-large-398b`` and
    ``deepseek-v3-671b`` configs on a (data=2, model=8) mesh must be
    ~1/16 of the unsharded cache (``slot_specs`` never pads, so the split
    is exact up to the replicated ``pos`` bookkeeping), and CORP pruning
    at 50% must shrink the hybrid's per-device cache strictly further
    (``eff_qk`` halves the K rows, ``d_inner_kept`` halves the SSM state
    — MLA latent caches are eff_qk-independent, so deepseek-v3 shards
    but does not shrink). Deviceless: specs come from the dict-mesh rule
    path and bytes from ``jax.eval_shape`` templates, so a 671B-class
    footprint is gated on single-device CPU CI; the live-mesh mirror of
    this gate is benchmarks/bench_serve_sharded.py."""
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.serve import device_bytes_estimate, slot_specs
    from repro.serve.cache import _infer_batch_axes, cache_bytes

    mesh = {"data": 2, "model": 8}
    n_dev = mesh["data"] * mesh["model"]
    SLOTS_FULL, LEN_FULL = 8, 4096

    def per_device(cfg):
        model = build_model(cfg)
        aparams = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

        def tmpl(b):
            req = {"tokens": jax.ShapeDtypeStruct((b, 8), jnp.int32)}
            return jax.eval_shape(
                lambda p, r: model.prefill(p, r, LEN_FULL)[1], aparams, req)

        t = tmpl(SLOTS_FULL)
        axes = _infer_batch_axes(tmpl(1), tmpl(2))
        specs = slot_specs(t, axes, mesh, name=cfg.name)
        return cache_bytes(t), device_bytes_estimate(t, specs, mesh)

    rows = []
    devs = {}
    for arch in ("jamba-1.5-large-398b", "deepseek-v3-671b"):
        for label, cfg in (("dense", get_config(arch)),
                           ("pruned 50%", get_config(arch).pruned(0.5, 0.5))):
            total, dev = per_device(cfg)
            devs[(arch, label)] = (total, dev)
            rows.append({"config": f"{arch} {label}",
                         "cache_gb": total / 2**30,
                         "per_device_gb": dev / 2**30,
                         "split": total / dev})
    print(format_table(rows))
    for (arch, label), (total, dev) in devs.items():
        if label == "dense":
            assert abs(dev - total / n_dev) <= 0.02 * total / n_dev, (
                f"{arch}: per-device cache {dev} not ~1/{n_dev} "
                f"of {total}")
    jd, jp = devs[("jamba-1.5-large-398b", "dense")][1], \
        devs[("jamba-1.5-large-398b", "pruned 50%")][1]
    assert jp < jd, (
        f"pruned jamba per-device cache not strictly smaller: {jp} >= {jd}")
    print(f"[bench_serve] GATE sharded footprint: 671B-class caches split "
          f"{n_dev}x per device on a 2x8 mesh, pruned jamba "
          f"{jp / 2**30:.3f} < {jd / 2**30:.3f} GiB dense per device")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--table-out", default=None,
                    help="write the routed-trace p50/p99 markdown table "
                         "here (CI uploads it as an artifact)")
    ap.add_argument("--sched-table-out", default=None,
                    help="write the chunked-prefill interference table "
                         "and the enc-dec mixed-load row here (CI "
                         "uploads it as an artifact)")
    args = ap.parse_args()

    cfg, model, params = trained_lm()
    trace = synthetic_trace(args.requests, cfg.vocab_size, **TRACE)
    total = sum(r.gen for r in trace)
    print(f"[bench_serve] {args.requests} requests, {total} tokens, "
          f"prompts {TRACE['prompt_range']}, gens {TRACE['gen_range']}, "
          f"{SLOTS} slots")

    comps_c, tc, eng = serve_continuous(model, params, trace)
    comps_s, ts = serve_static(model, params, trace)

    util = eng.stats["decode_lanes"] / max(
        1, eng.stats["decode_steps"] * SLOTS)
    print(f"[bench_serve] continuous: {eng.stats['decode_steps']} decode "
          f"steps at {util:.0%} lane utilization, "
          f"{eng.stats['refills']} slot refills")
    tc["mode"], ts["mode"] = "continuous", "static"
    keys = ["mode", "tokens", "tok_per_s", "lat_p50_ms", "lat_p99_ms",
            "ttft_p50_ms", "ttft_p99_ms"]
    print(format_table([tc, ts], keys))

    # gate: identical greedy streams
    for a, b in zip(comps_c, comps_s):
        assert list(a.tokens) == list(b.tokens), (
            f"continuous/static token divergence on rid {a.rid}")

    # gate: continuous batching must not lose to the batch barrier
    assert tc["tok_per_s"] >= ts["tok_per_s"], (
        f"continuous batching slower than static on a ragged trace: "
        f"{tc['tok_per_s']:.1f} vs {ts['tok_per_s']:.1f} tok/s")
    print(f"[bench_serve] GATE continuous >= static: "
          f"{tc['tok_per_s']:.1f} >= {ts['tok_per_s']:.1f} tok/s "
          f"({tc['tok_per_s'] / ts['tok_per_s']:.2f}x)")

    # front-end gates (ISSUE 6)
    gate_frontend_parity(model, params, trace, comps_c)
    gate_overload(model, params, cfg.vocab_size)
    gate_prefix_ttft(model, params)

    # fleet gates (ISSUE 7)
    gate_fleet_throughput(table_out=args.table_out)
    gate_fleet_parity(model, params, trace, comps_c)
    gate_drain()

    # scheduler gates (ISSUE 10)
    interference = gate_chunked_interference()
    gate_chunked_identity(model, params, trace, comps_c)
    mixed = mixedload_encdec_row()
    if args.sched_table_out:
        with open(args.sched_table_out, "w") as f:
            f.write(
                "# Scheduler interference: chunked vs unchunked prefill\n\n"
                "A 64-token prompt arrives (x3) while two slots decode\n"
                "steadily; fixed-cost fake engine (1 ms/decode step,\n"
                "1 ms/prompt token), so the decode gap measures the\n"
                "scheduler's interleaving, not device speed.\n\n"
                + interference + "\n\n"
                "# Mixed load: enc-dec encoder burst + steady decode\n\n"
                + mixed + "\n")
        print(f"[bench_serve] scheduler-trace tables -> "
              f"{args.sched_table_out}")

    # config-zoo gates (ISSUE 8)
    gate_recurrent_state_bytes()
    gate_expert_pruned_serving()

    # mesh-sharded footprint gate (ISSUE 9; live mirror in
    # benchmarks/bench_serve_sharded.py)
    gate_sharded_footprint()

    # dense vs pruned serving table
    print(f"[bench_serve] CORP prune @ {args.sparsity:.0%}")
    pruned, pcfg, _ = corp_prune(
        model, params, calib_lm(cfg),
        PruneConfig(args.sparsity, args.sparsity))
    pmodel = build_model(pcfg)
    _, tp, peng = serve_continuous(pmodel, pruned, trace)
    rows = []
    for name, t, e, p in (("dense", tc, eng, params),
                          (f"pruned {args.sparsity:.0%}", tp, peng, pruned)):
        rows.append({"model": name, "params": params_of(p),
                     "cache_kb": e.cache_bytes / 1e3,
                     "tok_per_s": t["tok_per_s"],
                     "lat_p50_ms": t["lat_p50_ms"],
                     "lat_p99_ms": t["lat_p99_ms"]})
    print(format_table(rows))

    assert peng.cache_bytes < eng.cache_bytes, (
        f"pruned slot cache not smaller: {peng.cache_bytes} vs "
        f"{eng.cache_bytes} bytes")
    print(f"[bench_serve] GATE pruned cache < dense: "
          f"{peng.cache_bytes / 1e3:.1f} < {eng.cache_bytes / 1e3:.1f} kB "
          f"(qk {cfg.d_head} -> {pcfg.eff_qk})")
    print("[bench_serve] all gates passed")


if __name__ == "__main__":
    main()
