"""Quickstart: the paper's pipeline end-to-end on CPU in ~3 minutes.

1. train a small DeiT-family ViT on a synthetic vision task,
2. CORP-prune it 50% (MLP + attention) with closed-form compensation,
3. compare against naive (rank-only) pruning,
4. report Top-1 / parameters / FLOPs — the Table-2 protocol.

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 300]

Calibration under the hood: ``corp_prune`` streams statistics through the
fused ``repro.core.calibrate.CalibrationEngine`` — one jitted step per
calibration batch runs the model once and reduces every unit's statistics
into a donated on-device accumulator (second moments via the Pallas gram
kernel on TPU). The engine is also usable standalone, e.g. to inspect
activation statistics without pruning::

    from repro.core import CalibrationEngine, discover_units
    engine = CalibrationEngine(model, discover_units(model.cfg), phase=1)
    stats = engine.run(params, calib_batches())   # {unit: {n, s1, s2, na}}

Long passes checkpoint + resume via ``corp_prune(..., ckpt_dir=...)``
(see repro.distrib.fault.CalibrationCheckpointer), and
``benchmarks/bench_calibration.py`` tracks fused-vs-per-unit-loop
throughput.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

from repro.core import PruneConfig, corp_prune  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--sparsity", type=float, default=0.875,
                    help="paper Fig. 2: the compensation gap grows with sparsity")
    args = ap.parse_args()
    os.environ["BENCH_VIT_STEPS"] = str(args.steps)

    from benchmarks.common import (calib_vit, forward_flops, params_of,
                                   trained_vit, vit_eval_acc)

    print("== 1. train (cached under benchmarks/_cache) ==")
    cfg, model, params = trained_vit()
    acc0 = vit_eval_acc(model, params)
    p0 = params_of(params)
    print(f"dense model: top1={acc0:.4f} params={p0/1e3:.0f}k")

    print(f"== 2. CORP one-shot prune @ {args.sparsity:.0%} ==")
    pruned, pcfg, report = corp_prune(
        model, params, calib_vit(cfg),
        PruneConfig(args.sparsity, args.sparsity), progress=print)
    m2 = build_model(pcfg)
    acc1 = vit_eval_acc(m2, pruned)

    print("== 3. naive (rank-only) baseline ==")
    naive, ncfg, _ = corp_prune(
        model, params, calib_vit(cfg),
        PruneConfig(args.sparsity, args.sparsity, compensate=False))
    acc2 = vit_eval_acc(build_model(ncfg), naive)

    print("== 4. results ==")
    b = {"images": jax.ShapeDtypeStruct((16, cfg.img_size, cfg.img_size, 3),
                                        jax.numpy.float32)}
    f0 = forward_flops(model, cfg, b)
    f1 = forward_flops(m2, pcfg, b)
    print(f"dense   : top1={acc0:.4f}  params={p0/1e3:7.0f}k  flops=1.00x")
    print(f"CORP    : top1={acc1:.4f}  params={params_of(pruned)/1e3:7.0f}k"
          f"  flops={f1/f0:.2f}x")
    print(f"naive   : top1={acc2:.4f}  (same shape as CORP)")
    print(f"CORP recovers {acc1-acc2:+.4f} Top-1 over naive pruning at "
          f"{args.sparsity:.0%} sparsity — zero gradients, one calibration "
          f"pass ({report['timing']})")


if __name__ == "__main__":
    main()
