"""CORP on a language model (the paper's OPT/Table-7 protocol).

Trains a small GQA LM (qwen2-family reduced) on a markov stream, prunes
MLP-only / attention-only / both at 30%, reports perplexity — then shows the
rope-aware class-2 compensator in action (DESIGN.md §2.2).

Run:  PYTHONPATH=src python examples/prune_llm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import PruneConfig, corp_prune  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    from benchmarks.common import calib_lm, lm_eval_ppl, trained_lm
    cfg, model, params = trained_lm()
    print(f"dense ppl = {lm_eval_ppl(model, params):.3f}")
    for tag, (sm, sa) in {"mlp": (0.3, 0.0), "attn": (0.0, 0.3),
                          "both": (0.3, 0.3)}.items():
        for comp in (True, False):
            p, c, rep = corp_prune(model, params, calib_lm(cfg),
                                   PruneConfig(sm, sa, compensate=comp))
            ppl = lm_eval_ppl(build_model(c), p)
            label = "CORP " if comp else "naive"
            print(f"{tag:5s} 30% {label}: ppl={ppl:.3f}")
        if sa > 0:
            # show the per-unit logit-recovery diagnostics (rho^2, Eq. 93)
            rho = [float(v["rho2"].mean()) for k, v in rep["units"].items()
                   if "attn" in k]
            if rho:
                print(f"      mean attention rho^2 (logit energy recovered "
                      f"by kept dims): {sum(rho)/len(rho):.3f}")


if __name__ == "__main__":
    main()
