"""End-to-end serving example: prune a trained LM with CORP, then serve a
ragged request trace through the continuous-batching engine, comparing dense
vs pruned latency percentiles and throughput — the paper's Table-5
efficiency protocol on the serving path (docs/serving.md).

Run:  PYTHONPATH=src python examples/serve_pruned.py [--requests 16]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import PruneConfig, corp_prune  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve import (ServeEngine, percentile_table,  # noqa: E402
                         synthetic_trace)
from repro.serve.engine import format_table  # noqa: E402


def serve(model, params, trace, *, slots, max_len):
    eng = ServeEngine(model, params, n_slots=slots, max_len=max_len)
    eng.warmup(prompt_lens=[len(r.tokens) for r in trace])
    t0 = time.perf_counter()
    comps = eng.run(trace)
    table = percentile_table(comps, time.perf_counter() - t0)
    table["cache_kb"] = eng.cache_bytes / 1e3
    return comps, table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--sparsity", type=float, default=0.5)
    args = ap.parse_args()

    from benchmarks.common import calib_lm, trained_lm
    cfg, model, params = trained_lm()
    prompt_range, gen_range = (8, 48), (4, 48)
    trace = synthetic_trace(args.requests, cfg.vocab_size, seed=0,
                            prompt_range=prompt_range, gen_range=gen_range)

    print(f"== dense serving ({args.requests} ragged requests, "
          f"{args.slots} slots) ==")
    _, t0r = serve(model, params, trace, slots=args.slots,
                   max_len=args.max_len)

    print(f"== CORP prune @ {args.sparsity:.0%} ==")
    pruned, pcfg, _ = corp_prune(model, params, calib_lm(cfg),
                                 PruneConfig(args.sparsity, args.sparsity))
    print("== pruned serving ==")
    _, t1r = serve(build_model(pcfg), pruned, trace, slots=args.slots,
                   max_len=args.max_len)

    t0r["model"], t1r["model"] = "dense", f"pruned {args.sparsity:.0%}"
    keys = ["model", "tokens", "tok_per_s", "lat_p50_ms", "lat_p99_ms",
            "ttft_p50_ms", "ttft_p99_ms", "cache_kb"]
    print(format_table([t0r, t1r], keys))
    print(f"decode speedup {t1r['tok_per_s'] / max(t0r['tok_per_s'], 1e-9):.2f}x, "
          f"KV cache {t0r['cache_kb'] / max(t1r['cache_kb'], 1e-9):.2f}x smaller "
          f"(qk {cfg.d_head} -> {pcfg.eff_qk} shrinks every slot's K rows)")


if __name__ == "__main__":
    main()
