"""End-to-end serving driver: prune a trained LM with CORP, then serve it
with batched requests (prefill + KV-cache decode), comparing dense vs pruned
latency/throughput — the paper's Table-5 efficiency protocol, on the serving
path.

Run:  PYTHONPATH=src python examples/serve_pruned.py [--gen 32]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import PruneConfig, corp_prune  # noqa: E402
from repro.launch.serve import serve_loop  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sparsity", type=float, default=0.5)
    args = ap.parse_args()

    from benchmarks.common import calib_lm, trained_lm
    cfg, model, params = trained_lm()
    max_len = args.prompt_len + args.gen + 1

    print(f"== dense serving ({args.batch} reqs x {args.prompt_len} prompt "
          f"+ {args.gen} gen) ==")
    _, tp0, td0 = serve_loop(model, params, batch=args.batch,
                             prompt_len=args.prompt_len, gen=args.gen,
                             max_len=max_len)

    print(f"== CORP prune @ {args.sparsity:.0%} ==")
    pruned, pcfg, _ = corp_prune(model, params, calib_lm(cfg),
                                 PruneConfig(args.sparsity, args.sparsity))
    m2 = build_model(pcfg)
    print("== pruned serving ==")
    _, tp1, td1 = serve_loop(m2, pruned, batch=args.batch,
                             prompt_len=args.prompt_len, gen=args.gen,
                             max_len=max_len)
    print(f"prefill speedup {tp0/max(tp1,1e-9):.2f}x, "
          f"decode speedup {td0/max(td1,1e-9):.2f}x "
          f"(KV cache K-side shrinks with the pruned qk dims)")


if __name__ == "__main__":
    main()
