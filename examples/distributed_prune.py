"""Distributed CORP: the statistics passes under pjit on a device mesh.

Runs the same one-shot pipeline on a (2,4) data x model mesh of 8 host
devices and verifies the pruned weights are bit-consistent with the
single-device result — the property that lets one calibration pass prune a
671B model on 512 chips (DESIGN.md §2.1).

NOTE: must run as its own process (device count is fixed at jax init):
    PYTHONPATH=src python examples/distributed_prune.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.core import PruneConfig, corp_prune  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    cfg = reduced(get_config("deit-base")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def calib():
        for i in range(4):
            yield {"images": jax.random.normal(
                jax.random.PRNGKey(i),
                (8, cfg.img_size, cfg.img_size, 3))}

    pc = PruneConfig(0.5, 0.5)
    print("== single device ==")
    p1, c1, _ = corp_prune(model, params, calib, pc, progress=print)

    mesh = make_mesh((2, 4))
    print(f"== mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} ==")
    with mesh:
        p2, c2, _ = corp_prune(model, params, calib, pc, progress=print)

    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(p1),
                               jax.tree.leaves(jax.device_get(p2))))
    print(f"max |single - mesh| over all pruned weights: {diff:.2e}")
    assert diff < 1e-3
    print("distributed CORP == single-device CORP  [OK]")


if __name__ == "__main__":
    main()
